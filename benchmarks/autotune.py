"""Cost-guided transfer-policy autotuner: search, prune statically, measure.

The policy space (scheme x delta x sharding x staging, per region) is too
large to hand-pick and too expensive to measure exhaustively.  This tool
closes the loop the ROADMAP asked for, in three stages per scenario:

  1. **Enumerate** the bounded candidate grid over the scenario's declared
     region structure (``repro.core.policy.enumerate_policies``:
     ``candidate_specs(mesh)`` per rule — 5^regions policies on a mesh,
     3^regions on one device).
  2. **Prune statically** with the cost model (``repro.analysis.cost``):
     rank every candidate by the calibrated wall estimate of one cold pass
     amortized over STEADY_WEIGHT steady passes; only the top-k survive.
     Zero device execution so far.
  3. **Measure** the survivors (plus the declared policy, always) with
     real ``TransferProgram`` runs through the differential harness
     (``run_policy_scenario``: every pass value- and motion-checked), and
     pick the measured winner.

Because the declared policy is always in the measured set, the winner is
measured <= declared by construction — asserted in ``--smoke``.  And
because the cost model's Motion half is a theorem, not an estimate, this
tool asserts static predicted bytes/calls == the measured ledger EXACTLY,
per region, cold and steady, for every program it runs — the
static/measured differential of DESIGN.md §14.

Writes one ``declared_vs_tuned`` row per scenario (schema v8, scheme
"autotune") to ``BENCH_autotune.json``; the calibrated device model
persists to ``BENCH_costmodel.json``.

    PYTHONPATH=src python -m benchmarks.autotune            # quick registry
    PYTHONPATH=src python -m benchmarks.autotune --smoke    # 2-scenario CI leg
    PYTHONPATH=src python -m benchmarks.autotune --calibrate  # refit model
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

SMOKE_FAMILIES = ("steady_reuse", "mixed_policy")


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _assert_prediction_exact(name: str, policy: str, pc: Any,
                             cold: Any, warm: Optional[Any]) -> None:
    """The theorem half: cost-model predicted Motion == measured ledger,
    exactly, totals and per region, cold and steady."""
    assert (pc.cold_bytes, pc.cold_calls) == (cold.h2d_bytes, cold.h2d_calls), (
        f"{name} [{policy}]: predicted cold ({pc.cold_bytes} B, "
        f"{pc.cold_calls} DMAs) != measured ({cold.h2d_bytes} B, "
        f"{cold.h2d_calls} DMAs)")
    for rc in pc.regions:
        led = cold.regions[rc.key]
        got = (led["h2d_bytes"], led["h2d_calls"])
        assert got == rc.cold.as_tuple(), (
            f"{name} [{policy}] region {rc.key!r}: predicted cold "
            f"{rc.cold.as_tuple()} != measured {got}")
    if warm is None:
        return
    assert (pc.steady_bytes, pc.steady_calls) == (warm.h2d_bytes,
                                                  warm.h2d_calls), (
        f"{name} [{policy}]: predicted steady ({pc.steady_bytes} B, "
        f"{pc.steady_calls} DMAs) != measured ({warm.h2d_bytes} B, "
        f"{warm.h2d_calls} DMAs)")
    for rc in pc.regions:
        led = warm.regions[rc.key]
        got = (led["h2d_bytes"], led["h2d_calls"])
        assert got == rc.steady.as_tuple(), (
            f"{name} [{policy}] region {rc.key!r}: predicted steady "
            f"{rc.steady.as_tuple()} != measured {got}")


def tune_scenario(sc: Any, model: Any, *, top_k: int = 4, passes: int = 3,
                  steady_weight: Optional[int] = None) -> Dict[str, Any]:
    """Search/prune/measure one scenario; returns its declared_vs_tuned
    row (schema v8).  Raises AssertionError on any value, motion or
    static/measured mismatch — the harness treats those as CI failures,
    never as data."""
    import jax

    from repro.analysis.cost import STEADY_WEIGHT, policy_cost
    from repro.core import TransferPolicy, enumerate_policies
    from repro.scenarios.driver import run_policy_scenario

    from .bench_schema import upgrade_row

    w = STEADY_WEIGHT if steady_weight is None else steady_weight
    tree = sc.build()
    mutate = list(sc.steady_mutate_paths())
    declared = sc.policy() or TransferPolicy.of("marshal")
    patterns = tuple(r.pattern for r in declared.rules)
    mesh = jax.device_count()

    # 1. enumerate the bounded grid over the declared region structure
    candidates = enumerate_policies(patterns, mesh_size=mesh)
    if declared not in candidates:
        candidates.append(declared)

    # 2. static prune: rank by the calibrated wall objective (no devices)
    costs = {p: policy_cost(tree, p, mutate) for p in candidates}
    ranked = sorted(candidates,
                    key=lambda p: (model.objective_us(costs[p], w), str(p)))
    survivors = ranked[:max(1, top_k)]
    if declared not in survivors:
        survivors.append(declared)

    # 3. measure the survivors; assert the motion theorem on every run
    measured: Dict[Any, Dict[str, float]] = {}
    for pol in survivors:
        ms = run_policy_scenario(sc, pol, tree=tree, passes=1 + max(1, passes))
        bad = [i for i, m in enumerate(ms) if not (m.ok and m.motion_ok)]
        assert not bad, (f"{sc.name} [{pol}]: value/motion check failed on "
                         f"pass(es) {bad}")
        cold, warm = ms[0], ms[1:]
        _assert_prediction_exact(sc.name, str(pol), costs[pol], cold, warm[0])
        steady_wall = min(m.wall_us for m in warm)
        measured[pol] = {
            "cold_wall_us": cold.wall_us,
            "steady_wall_us": steady_wall,
            "objective_us": cold.wall_us + w * steady_wall,
        }

    winner = min(measured, key=lambda p: (measured[p]["objective_us"],
                                          str(p)))
    pc = costs[winner]
    row = upgrade_row({
        "scenario": sc.name, "family": sc.family, "scheme": "autotune",
        "policy": str(declared), "tuned_policy": str(winner),
        "n_devices": mesh, "sharded": pc.policy.num_shards > 1,
        "declared_steady_wall_us": round(
            measured[declared]["steady_wall_us"], 2),
        "tuned_steady_wall_us": round(measured[winner]["steady_wall_us"], 2),
        "steady_wall_us": round(measured[winner]["steady_wall_us"], 2),
        "cached_wall_us": round(measured[winner]["cold_wall_us"], 2),
        "predicted_cold_wall_us": round(model.cold_wall_us(pc), 2),
        "predicted_steady_wall_us": round(model.steady_wall_us(pc), 2),
        "predicted_cold_bytes": pc.cold_bytes,
        "predicted_steady_bytes": pc.steady_bytes,
        "h2d_bytes": pc.cold_bytes, "h2d_calls": pc.cold_calls,
        "candidates": len(candidates), "measured": len(measured),
    })
    return row


def _load_model(path: str, calibrate: bool) -> Any:
    from repro.analysis.cost import CostModel

    if not calibrate and os.path.exists(path):
        return CostModel.load(path)
    model = CostModel.calibrate()
    model.save(path)
    print(f"calibrated device model -> {path}: latency {model.latency_us} "
          f"us/DMA, bandwidth {model.bandwidth_gbps} GB/s")
    return model


def run(size: str = "quick", only: Optional[Tuple[str, ...]] = None, *,
        top_k: int = 4, passes: int = 3, json_path: Optional[str] = None,
        calibrate: bool = False, smoke: bool = False) -> List[Dict[str, Any]]:
    from repro.scenarios import iter_scenarios

    model_path = os.path.join(_repo_root(), "BENCH_costmodel.json")
    model = _load_model(model_path, calibrate)
    scenarios = iter_scenarios(size, only=only)
    rows: List[Dict[str, Any]] = []
    print(f"{'scenario':<28} {'declared':<14} {'tuned':<14} "
          f"{'decl us':>9} {'tuned us':>9} {'pred us':>9}")
    for sc in scenarios:
        row = tune_scenario(sc, model, top_k=top_k, passes=passes)
        rows.append(row)
        decl_disp = row["policy"] if len(row["policy"]) <= 14 \
            else row["policy"][:11] + "..."
        tuned_disp = row["tuned_policy"] if len(row["tuned_policy"]) <= 14 \
            else row["tuned_policy"][:11] + "..."
        print(f"{row['scenario']:<28} {decl_disp:<14} {tuned_disp:<14} "
              f"{row['declared_steady_wall_us']:>9.1f} "
              f"{row['tuned_steady_wall_us']:>9.1f} "
              f"{row['predicted_steady_wall_us']:>9.1f}")
        if smoke:
            assert row["tuned_steady_wall_us"] \
                <= row["declared_steady_wall_us"] + 1e-9, (
                f"{row['scenario']}: tuned policy measured slower than "
                f"declared — the argmin invariant broke")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)
            f.write("\n")
        print(f"wrote {len(rows)} declared_vs_tuned rows -> {json_path}")
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.autotune",
        description="cost-guided policy autotuner: enumerate candidates, "
                    "prune with the static cost model, measure the top-k, "
                    "report declared vs tuned")
    ap.add_argument("--size", default="quick",
                    choices=("smoke", "quick", "full"))
    ap.add_argument("--only", default="",
                    help="comma-separated scenario families to tune")
    ap.add_argument("--smoke", action="store_true",
                    help="CI leg: smoke sizes, two small families "
                         f"({', '.join(SMOKE_FAMILIES)}), and assert the "
                         "tuned policy measured <= the declared one")
    ap.add_argument("--top-k", type=int, default=4,
                    help="statically ranked candidates to measure "
                         "(the declared policy is always measured too)")
    ap.add_argument("--passes", type=int, default=3,
                    help="steady passes per measured candidate")
    ap.add_argument("--calibrate", action="store_true",
                    help="refit the device model from live probe transfers "
                         "even if BENCH_costmodel.json exists")
    ap.add_argument("--json", default=None,
                    help="output row file (default BENCH_autotune.json at "
                         "the repo root; 'none' disables)")
    args = ap.parse_args(argv)

    size = "smoke" if args.smoke else args.size
    only = tuple(filter(None, args.only.split(","))) or None
    if args.smoke and only is None:
        only = SMOKE_FAMILIES
    json_path = args.json
    if json_path is None:
        json_path = os.path.join(_repo_root(), "BENCH_autotune.json")
    elif json_path == "none":
        json_path = None
    run(size, only, top_k=args.top_k, passes=args.passes,
        json_path=json_path, calibrate=args.calibrate, smoke=args.smoke)
    return 0


if __name__ == "__main__":
    sys.exit(main())
