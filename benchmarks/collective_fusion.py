"""Fused-collective benchmark (the paper's marshalling, applied to ICI).

Lowers the explicit-DP shard_map train step on an 8-device debug mesh under
three gradient schemes and counts collectives in the compiled HLO:

    pertensor   one psum per gradient leaf      (per-leaf deep copy / UVM-ish)
    arena       one psum per dtype bucket       (Algorithm 1 on the wire)
    arena+int8  bucket psum with shared-scale int8 + error feedback

Runs in a subprocess so XLA_FLAGS can force 8 host devices without touching
this process's device count.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.launch.mesh import make_debug_mesh
from repro.launch.hlo_analysis import collective_stats
from repro.models import registry
from repro.optim import make_optimizer, constant
from repro.runtime.train import (init_error_state, make_dp_train_step,
                                 train_state, abstract_train_state)

api = registry.get("llama3.2-1b", smoke=True)
opt = make_optimizer("sgdm")
mesh = make_debug_mesh(data=8, model=1)
state_abs = abstract_train_state(api, opt)
batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
out = {}
for scheme, compress in (("pertensor", False), ("arena", False),
                         ("arena", True)):
    step = make_dp_train_step(api, opt, constant(1e-3), mesh,
                              grad_scheme=scheme, compress=compress)
    err_abs = jax.tree_util.tree_map(
        lambda x: x, init_error_state(api, compress, mesh=mesh))
    lowered = jax.jit(step).lower(state_abs, batch_abs, err_abs)
    stats = collective_stats(lowered.compile().as_text())
    emitted = str(jax.make_jaxpr(step)(state_abs, batch_abs, err_abs)
                  ).count("psum")
    name = scheme + ("+int8" if compress else "")
    out[name] = {"count": stats["total_count"],
                 "bytes": stats["total_bytes"],
                 "emitted_psums": emitted,
                 "per_op": {k: v for k, v in stats["per_op"].items()
                            if v["count"]}}
print(json.dumps(out))
"""


def run(out=sys.stdout):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    if res.returncode != 0:
        print("collective_fusion FAILED:", res.stderr[-2000:], file=out)
        raise RuntimeError("collective fusion bench failed")
    data = json.loads(res.stdout.strip().splitlines()[-1])
    print("scheme,emitted_psums,compiled_collectives,collective_bytes", file=out)
    for name, s in data.items():
        print(f"{name},{s['emitted_psums']},{s['count']},{s['bytes']}", file=out)
    return data


if __name__ == "__main__":
    run()
