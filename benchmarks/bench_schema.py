"""BENCH_transfer.json row schema — versioned so trajectories stay comparable.

PRs keep adding columns to the steady-state transfer rows (the delta and
sharded columns arrived with the incremental/sharded engine); a naive
reader diffing BENCH_transfer.json across PRs would silently misalign old
and new rows.  Every row now carries ``"schema": N``; :func:`upgrade_row`
lifts any older row (including the schema-less v1 rows emitted before this
module existed) to the current version by filling the later columns with
their declared defaults, so cross-PR comparison code only ever sees
current-schema rows.

  v1  (implicit)  scenario, family, scheme, first_wall_us, cached_wall_us,
                  speedup, h2d_bytes, h2d_calls, enqueue_us, sync_us
  v2              + schema, skipped_bytes, delta_calls, sharded, n_devices,
                  per_device_bytes, per_device_calls, steady_wall_us,
                  steady_h2d_bytes
  v3              + spec (the canonical TransferSpec string the row ran
                  under), h2d_bytes_by_device, skipped_bytes_by_device
                  (the first-pass per-device ledger maps), steady_skipped_bytes
  v4              + policy (the canonical TransferPolicy string for
                  program rows, "" for plain spec rows), region_ledgers
                  (region pattern -> per-region first-pass ledger dict),
                  steady_region_ledgers (same keys, one warm program pass)

The ledger-derived column defaults come from ``TransferLedger().as_dict()``
rather than a hand-maintained list, so a ledger field added upstream
becomes a schema column (with its zero default) in one place.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from repro.core import TransferLedger

SCHEMA_VERSION = 4

# the ledger fields that are persisted per row, with the ledger's own
# zero-state as their defaults (timings are reported as *_us columns
# instead, and the d2h direction is not benched here).
LEDGER_COLUMNS = ("h2d_bytes", "h2d_calls", "skipped_bytes", "delta_calls",
                  "h2d_bytes_by_device", "skipped_bytes_by_device")
_LEDGER_DEFAULTS = {k: v for k, v in TransferLedger().as_dict().items()
                    if k in LEDGER_COLUMNS}

# column -> default, in schema order; upgrading fills what a row lacks.
V2_DEFAULTS: Dict[str, Any] = {
    "schema": SCHEMA_VERSION,
    "family": "",
    "skipped_bytes": _LEDGER_DEFAULTS["skipped_bytes"],
    "delta_calls": _LEDGER_DEFAULTS["delta_calls"],
    "sharded": False,
    "n_devices": 1,
    "per_device_bytes": None,  # uniform per-device split (sharded rows)
    "per_device_calls": None,
    "steady_wall_us": None,    # steady x delta: per-pass wall
    "steady_h2d_bytes": None,  # steady x delta: per-pass dirty bytes
}

V3_DEFAULTS: Dict[str, Any] = {
    "spec": "",                # canonical TransferSpec string ("" pre-v3)
    "h2d_bytes_by_device": _LEDGER_DEFAULTS["h2d_bytes_by_device"],
    "skipped_bytes_by_device": _LEDGER_DEFAULTS["skipped_bytes_by_device"],
    "steady_skipped_bytes": None,  # steady x delta: per-pass clean bytes
}

V4_DEFAULTS: Dict[str, Any] = {
    "policy": "",              # canonical TransferPolicy string ("" = spec row)
    "region_ledgers": {},      # region pattern -> cold-pass ledger dict
    "steady_region_ledgers": {},   # region pattern -> warm-pass ledger dict
}


def upgrade_row(row: Dict[str, Any]) -> Dict[str, Any]:
    """Lift a row of ANY past schema to SCHEMA_VERSION (old rows parse)."""
    version = int(row.get("schema", 1))
    if version > SCHEMA_VERSION:
        raise ValueError(f"row schema {version} is newer than this reader "
                         f"({SCHEMA_VERSION}); update benchmarks/bench_schema.py")
    out = dict(row)
    for defaults in (V2_DEFAULTS, V3_DEFAULTS, V4_DEFAULTS):
        for key, default in defaults.items():
            out.setdefault(key, dict(default) if isinstance(default, dict)
                           else default)
    out["schema"] = SCHEMA_VERSION
    return out


def load_rows(path: str) -> List[Dict[str, Any]]:
    """Read BENCH_transfer.json (any schema vintage) as current-schema rows."""
    with open(path) as f:
        rows = json.load(f)
    return [upgrade_row(r) for r in rows]


def row_key(row: Dict[str, Any]) -> Tuple[str, str, str]:
    """Trajectory identity of a row across PRs.  Policy rows key on the
    policy string too, so one scenario can carry several program rows (its
    declared policy plus any ``--policy`` requests) without colliding;
    plain spec rows keep their historical (scenario, scheme) identity with
    an empty third component."""
    return (row["scenario"], row["scheme"], row.get("policy") or "")


def compare(old_rows: List[Dict[str, Any]], new_rows: List[Dict[str, Any]],
            column: str = "cached_wall_us") -> List[Dict[str, Any]]:
    """Join two row sets (any schema vintage each) on (scenario, scheme) and
    report the per-cell trajectory of ``column``; rows that exist on only
    one side are reported with the other side ``None`` instead of being
    silently dropped."""
    old = {row_key(r): upgrade_row(r) for r in old_rows}
    new = {row_key(r): upgrade_row(r) for r in new_rows}
    out = []
    for key in sorted({*old, *new}):
        a: Optional[Dict] = old.get(key)
        b: Optional[Dict] = new.get(key)
        va = a.get(column) if a else None
        vb = b.get(column) if b else None
        ratio = (va / vb) if (va and vb) else None
        out.append({"scenario": key[0], "scheme": key[1], "policy": key[2],
                    f"old_{column}": va, f"new_{column}": vb,
                    "speedup": round(ratio, 2) if ratio else None})
    return out
