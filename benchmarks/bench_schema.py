"""BENCH_transfer.json row schema — versioned so trajectories stay comparable.

PRs keep adding columns to the steady-state transfer rows (the delta and
sharded columns arrived with the incremental/sharded engine); a naive
reader diffing BENCH_transfer.json across PRs would silently misalign old
and new rows.  Every row now carries ``"schema": N``; :func:`upgrade_row`
lifts any older row (including the schema-less v1 rows emitted before this
module existed) to the current version by filling the later columns with
their declared defaults, so cross-PR comparison code only ever sees
current-schema rows.

  v1  (implicit)  scenario, family, scheme, first_wall_us, cached_wall_us,
                  speedup, h2d_bytes, h2d_calls, enqueue_us, sync_us
  v2              + schema, skipped_bytes, delta_calls, sharded, n_devices,
                  per_device_bytes, per_device_calls, steady_wall_us,
                  steady_h2d_bytes
  v3              + spec (the canonical TransferSpec string the row ran
                  under), h2d_bytes_by_device, skipped_bytes_by_device
                  (the first-pass per-device ledger maps), steady_skipped_bytes
  v4              + policy (the canonical TransferPolicy string for
                  program rows, "" for plain spec rows), region_ledgers
                  (region pattern -> per-region first-pass ledger dict),
                  steady_region_ledgers (same keys, one warm program pass)
  v5              + overlap_wall_us (warm PIPELINED pass: caller-visible
                  wall, begin + residual sync + finish), sync_offload_us
                  (barrier time the pipelined pass kept off the caller's
                  thread: overlap_s - sync_s), finish_us (post-barrier
                  bookkeeping wall), ckpt_stall_us (train-loop rows only:
                  caller-visible cost of one zero-stall checkpoint save)
  v6              + restore_load_us / restore_reshard_us / restore_h2d_us
                  (elastic-restart rows: the restore wall split — disk
                  load, policy re-derivation + program compile, program
                  H2D + compute re-placement), restarts, policy_reshards
                  (stale policies re-derived on restore), mesh_from /
                  mesh_to (elastic n -> m device counts)
  v7              + serve rows (BENCH_serve.json — the first rows whose
                  unit is requests, not passes): requests, tokens,
                  tokens_per_s, p50_ms / p99_ms (request latency),
                  shed / timed_out / failed / retries (lifecycle counts),
                  fault_point ("" = clean leg), policy_fallbacks
                  (degradation-ladder rungs taken).  Serve rows set
                  steady_wall_us to the p99 latency in µs so the existing
                  --gate regression check covers them unchanged.
  v8              + autotune rows (scheme="autotune", one per tuned
                  scenario — benchmarks.autotune): tuned_policy (the
                  measured winner; the row's "policy" column carries the
                  DECLARED policy so the trajectory key stays stable),
                  declared_steady_wall_us / tuned_steady_wall_us (measured),
                  predicted_steady_wall_us / predicted_cold_wall_us (the
                  calibrated cost model's estimate for the winner),
                  predicted_cold_bytes / predicted_steady_bytes (the exact
                  Motion half — asserted == the measured ledger),
                  candidates / measured (search width: grid size, programs
                  actually run)

The ledger-derived column defaults come from ``TransferLedger().as_dict()``
rather than a hand-maintained list, so a ledger field added upstream
becomes a schema column (with its zero default) in one place.

Run ``python -m benchmarks.bench_schema old.json new.json --gate`` to use
:func:`gate` as a CI regression gate: it joins the freshly emitted rows
against the committed baseline and FAILS (exit 1) on any steady-wall
regression beyond the threshold (default 1.5x).  ``--baseline`` is the
richer CI mode: the same gate PLUS a full per-row steady-wall diff report
(old → new, ratio, added/retired rows), so the build log shows the whole
trajectory, not just the failures.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

from repro.core import TransferLedger

SCHEMA_VERSION = 8

# the ledger fields that are persisted per row, with the ledger's own
# zero-state as their defaults (timings are reported as *_us columns
# instead, and the d2h direction is not benched here).
LEDGER_COLUMNS = ("h2d_bytes", "h2d_calls", "skipped_bytes", "delta_calls",
                  "h2d_bytes_by_device", "skipped_bytes_by_device")
_LEDGER_DEFAULTS = {k: v for k, v in TransferLedger().as_dict().items()
                    if k in LEDGER_COLUMNS}

# column -> default, in schema order; upgrading fills what a row lacks.
V2_DEFAULTS: Dict[str, Any] = {
    "schema": SCHEMA_VERSION,
    "family": "",
    "skipped_bytes": _LEDGER_DEFAULTS["skipped_bytes"],
    "delta_calls": _LEDGER_DEFAULTS["delta_calls"],
    "sharded": False,
    "n_devices": 1,
    "per_device_bytes": None,  # uniform per-device split (sharded rows)
    "per_device_calls": None,
    "steady_wall_us": None,    # steady x delta: per-pass wall
    "steady_h2d_bytes": None,  # steady x delta: per-pass dirty bytes
}

V3_DEFAULTS: Dict[str, Any] = {
    "spec": "",                # canonical TransferSpec string ("" pre-v3)
    "h2d_bytes_by_device": _LEDGER_DEFAULTS["h2d_bytes_by_device"],
    "skipped_bytes_by_device": _LEDGER_DEFAULTS["skipped_bytes_by_device"],
    "steady_skipped_bytes": None,  # steady x delta: per-pass clean bytes
}

V4_DEFAULTS: Dict[str, Any] = {
    "policy": "",              # canonical TransferPolicy string ("" = spec row)
    "region_ledgers": {},      # region pattern -> cold-pass ledger dict
    "steady_region_ledgers": {},   # region pattern -> warm-pass ledger dict
}

V5_DEFAULTS: Dict[str, Any] = {
    "overlap_wall_us": None,   # warm pipelined pass: caller-visible wall
    "sync_offload_us": None,   # barrier time kept off the caller's thread
    "finish_us": None,         # post-barrier bookkeeping wall (warm pass)
    "ckpt_stall_us": None,     # train-loop rows: one zero-stall save's cost
}

V6_DEFAULTS: Dict[str, Any] = {
    "restore_load_us": None,     # elastic rows: checkpoint disk -> host wall
    "restore_reshard_us": None,  # policy re-derivation + program compile wall
    "restore_h2d_us": None,      # program H2D pass + compute re-placement wall
    "restarts": None,            # loop restarts the row's run survived
    "policy_reshards": None,     # stale policies re-derived on restore
    "mesh_from": None,           # elastic restart: devices before the crash
    "mesh_to": None,             # devices the survivor restored onto
}

V7_DEFAULTS: Dict[str, Any] = {
    "requests": None,            # serve rows: requests submitted this leg
    "tokens": None,              # tokens generated across the leg
    "tokens_per_s": None,        # leg throughput
    "p50_ms": None,              # per-request latency percentiles (accepted
    "p99_ms": None,              #   requests, submit -> terminal)
    "shed": None,                # admission-shed requests
    "timed_out": None,           # deadline-expired requests
    "failed": None,              # typed-failure requests
    "retries": None,             # transient-fault retries across the leg
    "fault_point": None,         # injected serve.* point ("" = clean leg)
    "policy_fallbacks": None,    # degradation-ladder rungs taken
}

V8_DEFAULTS: Dict[str, Any] = {
    "tuned_policy": None,             # autotune rows: the measured winner
    "declared_steady_wall_us": None,  # measured, declared policy
    "tuned_steady_wall_us": None,     # measured, tuned winner
    "predicted_steady_wall_us": None,  # cost model estimate for the winner
    "predicted_cold_wall_us": None,
    "predicted_cold_bytes": None,     # exact Motion half (== ledger)
    "predicted_steady_bytes": None,
    "candidates": None,               # bounded grid size for the scenario
    "measured": None,                 # programs actually run (post-prune)
}


def upgrade_row(row: Dict[str, Any]) -> Dict[str, Any]:
    """Lift a row of ANY past schema to SCHEMA_VERSION (old rows parse)."""
    version = int(row.get("schema", 1))
    if version > SCHEMA_VERSION:
        raise ValueError(f"row schema {version} is newer than this reader "
                         f"({SCHEMA_VERSION}); update benchmarks/bench_schema.py")
    out = dict(row)
    for defaults in (V2_DEFAULTS, V3_DEFAULTS, V4_DEFAULTS, V5_DEFAULTS,
                     V6_DEFAULTS, V7_DEFAULTS, V8_DEFAULTS):
        for key, default in defaults.items():
            out.setdefault(key, dict(default) if isinstance(default, dict)
                           else default)
    out["schema"] = SCHEMA_VERSION
    return out


def load_rows(path: str) -> List[Dict[str, Any]]:
    """Read BENCH_transfer.json (any schema vintage) as current-schema rows."""
    with open(path) as f:
        rows = json.load(f)
    return [upgrade_row(r) for r in rows]


def row_key(row: Dict[str, Any]) -> Tuple[str, str, str]:
    """Trajectory identity of a row across PRs.  Policy rows key on the
    policy string too, so one scenario can carry several program rows (its
    declared policy plus any ``--policy`` requests) without colliding;
    plain spec rows keep their historical (scenario, scheme) identity with
    an empty third component."""
    return (row["scenario"], row["scheme"], row.get("policy") or "")


def compare(old_rows: List[Dict[str, Any]], new_rows: List[Dict[str, Any]],
            column: str = "cached_wall_us") -> List[Dict[str, Any]]:
    """Join two row sets (any schema vintage each) on (scenario, scheme) and
    report the per-cell trajectory of ``column``; rows that exist on only
    one side are reported with the other side ``None`` instead of being
    silently dropped."""
    old = {row_key(r): upgrade_row(r) for r in old_rows}
    new = {row_key(r): upgrade_row(r) for r in new_rows}
    out = []
    for key in sorted({*old, *new}):
        a: Optional[Dict] = old.get(key)
        b: Optional[Dict] = new.get(key)
        va = a.get(column) if a else None
        vb = b.get(column) if b else None
        ratio = (va / vb) if (va and vb) else None
        out.append({"scenario": key[0], "scheme": key[1], "policy": key[2],
                    f"old_{column}": va, f"new_{column}": vb,
                    "speedup": round(ratio, 2) if ratio else None})
    return out


def gate(old_rows: List[Dict[str, Any]], new_rows: List[Dict[str, Any]],
         threshold: float = 1.5) -> List[Dict[str, Any]]:
    """The CI regression gate: every row whose steady-state wall regressed
    beyond ``threshold`` (new > old * threshold).  Each row pair gates on
    ``steady_wall_us`` where both sides have it (warm passes), falling back
    to ``cached_wall_us`` (cold-cache rows and pre-v2 baselines); rows
    present on only one side never gate — adding or retiring a scenario is
    not a regression."""
    old = {row_key(r): upgrade_row(r) for r in old_rows}
    new = {row_key(r): upgrade_row(r) for r in new_rows}
    failures: List[Dict[str, Any]] = []
    for key in sorted(set(old) & set(new)):
        a, b = old[key], new[key]
        for column in ("steady_wall_us", "cached_wall_us"):
            va, vb = a.get(column), b.get(column)
            if not va or not vb:
                continue
            if vb > va * threshold:
                failures.append({
                    "scenario": key[0], "scheme": key[1], "policy": key[2],
                    "column": column, "old_us": va, "new_us": vb,
                    "ratio": round(vb / va, 2), "threshold": threshold})
            break  # gate each row on its best available column only
    return failures


def baseline_diff(old_rows: List[Dict[str, Any]],
                  new_rows: List[Dict[str, Any]]
                  ) -> List[Dict[str, Any]]:
    """The full per-row steady-wall trajectory for the --baseline report:
    one cell per row key with the gate's own column choice (steady wall
    where both sides have it, else cached wall), plus ``status`` —
    ``both`` / ``added`` / ``retired``."""
    old = {row_key(r): upgrade_row(r) for r in old_rows}
    new = {row_key(r): upgrade_row(r) for r in new_rows}
    out: List[Dict[str, Any]] = []
    for key in sorted({*old, *new}):
        a, b = old.get(key), new.get(key)
        column, va, vb = "steady_wall_us", None, None
        for column in ("steady_wall_us", "cached_wall_us"):
            va = a.get(column) if a else None
            vb = b.get(column) if b else None
            if (va or not a) and (vb or not b):
                break
        status = "both" if a and b else ("added" if b else "retired")
        ratio = round(vb / va, 2) if va and vb else None
        out.append({"scenario": key[0], "scheme": key[1], "policy": key[2],
                    "column": column, "old_us": va, "new_us": vb,
                    "ratio": ratio, "status": status})
    return out


def run_baseline(old_path: str, new_path: str,
                 threshold: float = 1.5) -> int:
    """The CI --baseline verdict: print the full steady-wall diff of the
    fresh rows against the committed baseline, then apply :func:`gate`.
    Returns a process exit code — 0 clean, 1 on any regression beyond
    ``threshold`` — shared by the bench_schema CLI and
    ``benchmarks.run --baseline``."""
    old_rows, new_rows = load_rows(old_path), load_rows(new_path)
    cells = baseline_diff(old_rows, new_rows)
    print(f"baseline diff: {old_path} -> {new_path} "
          f"({len(old_rows)} -> {len(new_rows)} rows)")
    for c in cells:
        name = "/".join(p for p in (c["scenario"], c["scheme"],
                                    c["policy"]) if p)
        if c["status"] != "both":
            print(f"  {name}: {c['status']}")
            continue
        old_us = f"{c['old_us']:.1f}" if c["old_us"] else "-"
        new_us = f"{c['new_us']:.1f}" if c["new_us"] else "-"
        ratio = f" ({c['ratio']}x)" if c["ratio"] else ""
        print(f"  {name}: {c['column']} {old_us} -> {new_us} us{ratio}")
    failures = gate(old_rows, new_rows, threshold=threshold)
    if failures:
        print(f"BASELINE GATE FAILED: {len(failures)} row(s) regressed "
              f">{threshold}x")
        for f in failures:
            name = "/".join(p for p in
                            (f["scenario"], f["scheme"], f["policy"]) if p)
            print(f"  {name}: {f['column']} {f['old_us']:.1f} -> "
                  f"{f['new_us']:.1f} us ({f['ratio']}x)")
        return 1
    print(f"baseline gate passed (threshold {threshold}x, "
          f"{len(new_rows)} fresh rows)")
    return 0


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="diff two BENCH_transfer.json row sets; --gate fails "
                    "the build on steady-wall regression, --baseline adds "
                    "the full per-row trajectory report to the same gate")
    ap.add_argument("old", help="baseline rows (committed BENCH_transfer.json)")
    ap.add_argument("new", help="freshly emitted rows")
    ap.add_argument("--gate", action="store_true",
                    help="exit 1 if any row regressed past --threshold")
    ap.add_argument("--baseline", action="store_true",
                    help="CI mode: print the full steady-wall diff against "
                         "the committed baseline AND apply the gate "
                         "(exit 1 on regression past --threshold)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="regression ratio that fails the gate (default 1.5)")
    ap.add_argument("--column", default="cached_wall_us",
                    help="column for the plain (non-gate) diff report")
    args = ap.parse_args(argv)
    if args.baseline:
        return run_baseline(args.old, args.new, threshold=args.threshold)
    old_rows, new_rows = load_rows(args.old), load_rows(args.new)
    if args.gate:
        failures = gate(old_rows, new_rows, threshold=args.threshold)
        if failures:
            print(f"PERF GATE FAILED: {len(failures)} row(s) regressed "
                  f">{args.threshold}x")
            for f in failures:
                name = "/".join(p for p in
                                (f["scenario"], f["scheme"], f["policy"]) if p)
                print(f"  {name}: {f['column']} {f['old_us']:.1f} -> "
                      f"{f['new_us']:.1f} us ({f['ratio']}x)")
            return 1
        print(f"perf gate passed (threshold {args.threshold}x, "
              f"{len(new_rows)} fresh rows)")
        return 0
    for cell in compare(old_rows, new_rows, column=args.column):
        print(cell)
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
