"""Benchmark driver: one section per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run            # full suite
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized sweep
    PYTHONPATH=src python -m benchmarks.run --smoke    # registry smoke only

Sections (paper artifact -> module):
    datasize            Eq. 1-3 / Tables 1-2     benchmarks.datasize
    linear              §4.1 / Figs. 5-6         benchmarks.linear_scenario
    dense               §4.2 / Fig. 7            benchmarks.dense_scenario
    transfer            registry x scheme steady state benchmarks.transfer_steady
    transfer_overlap    pipelined executor overlap     benchmarks.transfer_overlap
    elastic             n -> m restart restore split   benchmarks.elastic_restart
    serve               open-loop request stream       benchmarks.serve_load
    instructions        §6.3 / Tables 3-4        benchmarks.instruction_count
    marshal_kernel      Alg. 1 as a TPU kernel   benchmarks (inline)
    checkpoint          marshalled ckpt I/O      benchmarks.checkpoint_bench
    collective_fusion   arena-fused psums        benchmarks.collective_fusion
    roofline            §Roofline summary        benchmarks.roofline

The transfer section iterates the full ``repro.scenarios`` registry and
writes ``BENCH_transfer.json`` (repo root) in the schema-versioned row
format of ``benchmarks.bench_schema`` (v6): TransferSpec x scenario x
{spec, first_wall_us, cached_wall_us, h2d_bytes, h2d_calls, enqueue_us,
sync_us, skipped_bytes, delta_calls, sharded, n_devices, per_device_*,
*_by_device, steady_*} plus one PROGRAM row per scenario policy ({policy,
region_ledgers, steady_region_ledgers, overlap_wall_us, sync_offload_us,
finish_us}) — the machine-readable perf trajectory (compare across PRs
with ``scripts/update_experiments.py --transfer --old prev.json``, gate
regressions with ``python -m benchmarks.bench_schema old new --gate``;
old-schema rows still parse).  ``--smoke``
runs ONLY the registry sweep at tiny sizes (benchmarks.smoke), including
the steady-state delta contracts of the steady_reuse/sharded_delta
families and every scenario's declared policy program, and fails on any
value- or data-motion-check mismatch: the CI harness-breakage canary.
``--spec`` (comma-separated canonical spec strings, e.g.
``marshal+delta@dp8``) narrows the smoke and transfer sweeps to those
specs; ``--policy`` (repeatable policy strings, e.g.
``'params/**=marshal+delta@dp8; **=marshal'``) compiles each into a
TransferProgram over every scenario tree and enforces the per-region
ledger contracts.  ``--async`` additionally drives every policy program
through the PIPELINED executor (``to_device_async``) in the smoke sweep —
same trees, same per-region contracts, async==sync enforced as a failure.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _section(name):
    print(f"\n===== {name} =====", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="registry x spec sweep at tiny sizes, then exit "
                         "(fails on check/data-motion mismatches)")
    ap.add_argument("--spec", default="",
                    help="comma-separated TransferSpec strings (e.g. "
                         "marshal+delta@dp8) restricting the smoke/transfer "
                         "sweeps; legacy scheme names also parse")
    ap.add_argument("--policy", action="append", default=[],
                    help="path-scoped TransferPolicy string (repeatable), "
                         "e.g. 'params/**=marshal+delta@dp8; **=marshal' — "
                         "compiled into a TransferProgram over every "
                         "scenario tree in the smoke/transfer sweeps")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="smoke: drive every policy program through the "
                         "pipelined executor too (async==sync enforced)")
    ap.add_argument("--skip", default="",
                    help="comma-separated section names to skip")
    ap.add_argument("--baseline", default="",
                    help="committed BENCH_transfer.json to diff the fresh "
                         "rows against after the transfer+elastic sections "
                         "(bench_schema --baseline; exits 1 on steady-wall "
                         "regression)")
    args = ap.parse_args(argv)
    skip = set(filter(None, args.skip.split(",")))
    specs = list(filter(None, args.spec.split(","))) or None
    policies = [p for p in args.policy if p.strip()] or None
    t0 = time.time()

    if args.smoke:
        _section("scenario registry smoke (all scenarios x all specs)")
        from . import smoke
        smoke.run(specs=specs, policies=policies, async_executor=args.async_)
        print(f"\n[benchmarks.run] done in {time.time() - t0:.1f}s")
        return

    if "datasize" not in skip:
        _section("datasize (Eq. 1-3, Tables 1-2)")
        from . import datasize
        import io
        buf = io.StringIO()
        datasize.run(out=buf)
        lines = buf.getvalue().splitlines()
        print("\n".join(lines[:8] + [f"... ({len(lines)} rows total)"]))

    if "linear" not in skip:
        _section("linear scenario (Figs. 5-6)")
        from . import linear_scenario
        if args.quick:
            linear_scenario.run(ks=(2, 6), ns=(10**3,), repeats=1)
        else:
            linear_scenario.run()

    if "dense" not in skip:
        _section("dense scenario (Fig. 7)")
        from . import dense_scenario
        if args.quick:
            dense_scenario.run(qs=(4,), ns=(10**3,), repeats=1)
        else:
            dense_scenario.run()

    if "transfer" not in skip:
        _section("transfer steady state (arena engine, first vs cached call)")
        from . import transfer_steady
        json_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_transfer.json")
        transfer_steady.run(quick=args.quick,
                            repeats=3 if args.quick else 5,
                            json_path=json_path, specs=specs,
                            policies=policies)

    if "transfer_overlap" not in skip:
        _section("transfer overlap (pipelined executor, zero-stall ckpt)")
        from . import transfer_overlap
        json_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_overlap.json")
        transfer_overlap.run(quick=args.quick,
                             repeats=3 if args.quick else 5,
                             json_path=json_path)

    if "elastic" not in skip:
        _section("elastic restart (n -> m mesh restore, trajectory asserted)")
        from . import elastic_restart
        json_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_transfer.json")
        # runs AFTER the transfer section on purpose: transfer_steady owns
        # and rewrites BENCH_transfer.json; elastic rows merge into it
        elastic_restart.run_bench(quick=args.quick, json_path=json_path)

    if args.baseline:
        # after the transfer+elastic sections have rewritten the fresh row
        # file: diff it against the committed baseline and fail loudly on a
        # steady-wall regression (bench_schema --baseline semantics)
        _section(f"baseline diff (vs {args.baseline})")
        from . import bench_schema
        fresh = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_transfer.json")
        rc = bench_schema.run_baseline(args.baseline, fresh)
        if rc:
            sys.exit(rc)

    if "serve" not in skip:
        _section("serve load (open-loop request stream, faulted legs)")
        from . import serve_load
        json_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
        serve_load.run_bench(preset="quick" if args.quick else "full",
                             json_path=json_path)

    if "instructions" not in skip:
        _section("instruction count (Tables 3-4)")
        from . import instruction_count
        instruction_count.run(ks=(2, 4, 6, 8, 10) if args.quick
                              else (2, 3, 4, 5, 6, 7, 8, 9, 10))

    if "marshal_kernel" not in skip:
        _section("marshal_pack kernel (Alg. 1 on TPU, interpret on CPU)")
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.kernels.marshal_pack import kernel as mk
        from .timer import bench
        n_tiles = 64
        src = jnp.asarray(np.random.default_rng(0).standard_normal(
            (n_tiles * mk.SUBLANE, mk.LANE)), jnp.float32)
        tmap = jnp.asarray(np.random.default_rng(1).permutation(n_tiles)
                           .astype(np.int32))
        fn = lambda: jax.block_until_ready(  # lint: allow=DC201 -- timed kernel sync

            mk.gather_tiles(src, tmap, interpret=True))
        r = bench("marshal_pack_interpret", fn, min_time=0.05, repeats=2)
        mb = src.nbytes / 1e6
        print("name,us_per_call,derived")
        print(r.csv(f"{mb:.2f}MB/call (interpret-mode: correctness proxy)"))

    if "checkpoint" not in skip:
        _section("checkpoint (marshalled vs per-leaf)")
        from . import checkpoint_bench
        checkpoint_bench.run()

    if "collective_fusion" not in skip:
        _section("collective fusion (arena psum vs per-tensor)")
        from . import collective_fusion
        try:
            collective_fusion.run()
        except Exception as e:  # subprocess-heavy; report, don't die
            print(f"collective_fusion skipped: {e}")

    if "roofline" not in skip:
        _section("roofline summary (from artifacts/dryrun)")
        art = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "artifacts", "dryrun")
        if os.path.isdir(art) and os.listdir(art):
            from . import roofline
            rows = roofline.run(art)
            print(f"({len(rows)} cells analysed)")
        else:
            print("no dry-run artifacts found; run "
                  "`python -m repro.launch.dryrun --all --mesh both "
                  "--out artifacts/dryrun` first")

    print(f"\n[benchmarks.run] done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
