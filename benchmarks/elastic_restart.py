"""Elastic-restart benchmark: the restore path's deep copy, measured.

The paper's deep copy run at the worst possible moment: the cluster
shrank, the checkpoint must re-place onto a DIFFERENT mesh, and the state
policy the survivor was handed still names the dead one.  One episode:

1. reference: an uninterrupted ``num_steps`` run (the trajectory oracle);
2. :func:`repro.runtime.run_elastic` trains on an n-device mesh, kills the
   incarnation at ``crash_step``, then restores onto ``m != n`` devices
   through the loop's re-derived state policy (``policy_reshards`` counts
   the re-derivation) and runs to completion.

Correctness is asserted, not reported: the resumed trajectory must be
bit-identical to the reference (:func:`trajectory_diff` — the
deterministic ``(seed, step, rank)`` pipeline replays exactly, and a
restore is a transfer, not arithmetic).

The row (schema v6, ``benchmarks.bench_schema``) records the restore wall
split — ``restore_load_us`` (checkpoint disk -> host), ``restore_reshard_us``
(policy re-derivation + program compile), ``restore_h2d_us`` (program H2D
pass + compute re-placement) — plus ``mesh_from``/``mesh_to`` and
``policy_reshards``.  Rows MERGE into ``BENCH_transfer.json`` (same-key
rows replaced, everything else kept), since ``benchmarks.transfer_steady``
owns and rewrites that file earlier in a ``benchmarks.run`` sweep.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
from typing import List, Optional

import jax

from repro.data import SyntheticLM
from repro.models import registry
from repro.optim import constant, make_optimizer
from repro.runtime import (make_train_step, run, run_elastic, train_state,
                           trajectory_diff)
from repro.runtime.train import state_transfer_policy

from .bench_schema import SCHEMA_VERSION, row_key, upgrade_row

_COLS = ("scenario,mesh_from,mesh_to,policy_reshards,restore_load_us,"
         "restore_reshard_us,restore_h2d_us,restore_wall_us")


def _episode_row(n: int, m: int, num_steps: int, crash_step: int,
                 ckpt_every: int, out) -> dict:
    api = registry.get("llama3.2-1b", smoke=True)
    opt = make_optimizer("adamw")
    step = jax.jit(make_train_step(api, opt, constant(1e-2)))
    data = SyntheticLM(api.cfg.vocab_size, seq_len=32, global_batch=4)
    init = lambda: train_state(api, opt, jax.random.PRNGKey(11))
    data_fn = lambda s: data.batch(s)

    reference = run(step, init, data_fn, num_steps)
    tmp = tempfile.mkdtemp(prefix="elastic_restart_")
    try:
        res = run_elastic(step, init, data_fn, num_steps, ckpt_dir=tmp,
                          crash_step=crash_step, n_devices=n, m_devices=m,
                          ckpt_every=ckpt_every,
                          policy_fn=state_transfer_policy)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    bad = trajectory_diff(reference.metrics_history,
                          res.result.metrics_history)
    assert not bad, (
        f"elastic restart n={n} -> m={m} diverged from the uninterrupted "
        f"trajectory:\n" + "\n".join(bad))
    split = res.restore_split
    assert split is not None, "the survivor incarnation never restored"
    if n != m:
        assert res.result.policy_reshards >= 1, (
            f"the stale dp{n} policy was not re-derived for m={m}")
    load_us = split["load_s"] * 1e6
    reshard_us = split["reshard_s"] * 1e6
    h2d_us = split["h2d_s"] * 1e6
    wall_us = load_us + reshard_us + h2d_us
    row = dict(schema=SCHEMA_VERSION,
               scenario=f"elastic_restart_n{n}_m{m}", family="elastic",
               scheme="elastic-restart", spec="",
               policy=str(state_transfer_policy(n)),  # what the survivor GOT
               first_wall_us=round(wall_us, 1),
               cached_wall_us=round(wall_us, 1),
               speedup=None, h2d_bytes=0, h2d_calls=0,
               enqueue_us=None, sync_us=None,
               restore_load_us=round(load_us, 1),
               restore_reshard_us=round(reshard_us, 1),
               restore_h2d_us=round(h2d_us, 1),
               restarts=1,                       # one process-level restart
               policy_reshards=res.result.policy_reshards,
               mesh_from=n, mesh_to=m,
               n_devices=m, sharded=m > 1,
               restored_step=res.restored_step, crash_step=res.crash_step)
    row = upgrade_row(row)
    print(f"{row['scenario']},{n},{m},{row['policy_reshards']},"
          f"{row['restore_load_us']},{row['restore_reshard_us']},"
          f"{row['restore_h2d_us']},{round(wall_us, 1)}", file=out)
    return row


def _merge_json(rows: List[dict], json_path: str, out) -> None:
    """Replace same-key rows in an existing BENCH_transfer.json, keep the
    rest (the transfer section owns the file and rewrites it wholesale)."""
    existing: List[dict] = []
    if os.path.exists(json_path):
        with open(json_path) as f:
            existing = json.load(f)
    fresh = {row_key(r) for r in rows}
    merged = [r for r in existing if row_key(upgrade_row(r)) not in fresh]
    merged.extend(rows)
    with open(json_path, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"[elastic_restart] merged {len(rows)} row(s) into {json_path} "
          f"({len(merged)} total, schema v{SCHEMA_VERSION})", file=out)


def run_bench(n: Optional[int] = None, m: Optional[int] = None,
              quick: bool = False, steps: Optional[int] = None,
              crash_step: Optional[int] = None, ckpt_every: int = 4,
              json_path: Optional[str] = None, out=sys.stdout) -> List[dict]:
    n = n if n is not None else jax.device_count()
    m = m if m is not None else max(1, n // 2)
    visible = jax.device_count()
    if m > visible:
        raise SystemExit(f"--m {m} exceeds the {visible} visible device(s); "
                         f"set XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count={m} to emulate on CPU")
    steps = steps if steps is not None else (12 if quick else 24)
    crash_step = crash_step if crash_step is not None \
        else max(ckpt_every + 1, steps * 3 // 4)
    print(_COLS, file=out)
    rows = [_episode_row(n, m, steps, crash_step, ckpt_every, out)]
    if n != m:
        # control: same-mesh restart (no reshard) — the n -> m delta over
        # this row is the price of elasticity itself
        rows.append(_episode_row(m, m, steps, crash_step, ckpt_every, out))
    print(f"[elastic_restart] n={n} -> m={m}: trajectory bit-identical, "
          f"restore split recorded", file=out)
    if json_path:
        _merge_json(rows, json_path, out)
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="elastic-restart benchmark: n -> m mesh restore, "
                    "bit-identical trajectory asserted")
    ap.add_argument("--n", type=int, default=None,
                    help="pre-crash mesh size (default: every visible device)")
    ap.add_argument("--m", type=int, default=None,
                    help="surviving mesh size (default: max(1, n // 2))")
    ap.add_argument("--quick", action="store_true", help="12 steps, not 24")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--crash-step", type=int, default=None)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--json", default=None,
                    help="merge rows into this BENCH_transfer.json")
    args = ap.parse_args(argv)
    run_bench(n=args.n, m=args.m, quick=args.quick, steps=args.steps,
              crash_step=args.crash_step, ckpt_every=args.ckpt_every,
              json_path=args.json)


if __name__ == "__main__":
    main()
