"""Serve load benchmark: the first rows whose unit is REQUESTS, not passes.

A synthetic open-loop request stream (arrivals keep coming whether or not
the server keeps up — the millions-of-users shape) drives the resilient
server of ``repro.runtime.serve``: mixed prompt lengths, slot churn
(requests outnumber slots several times over), and a bounded admission
queue.  Each leg reports tokens/sec and p50/p99 request latency
(submit -> terminal) as a schema-v7 row (``benchmarks.bench_schema``)
into ``BENCH_serve.json``.

Legs:

  * ``clean``      — no faults: the throughput/latency baseline.
  * ``overload``   — a shed watermark far below the arrival count: proves
                     backpressure answers (shed > 0) instead of buffering
                     without bound; latency is measured over the admitted
                     requests only.
  * one leg per ``serve.*`` fault point — an injected kill mid-pack /
    mid-decode / mid-refill / mid-policy-swap.  Each faulted leg asserts
    the lifecycle contract: every submitted rid terminates in exactly one
    state, the server stays up (completions continue after the fault),
    and in the smoke preset the faulted p99 stays bounded
    (< ``P99_BOUND`` x the clean p99).

Rows set ``steady_wall_us`` to the p99 latency in µs, so the existing
``bench_schema --gate`` regression check covers serving with no new
machinery (CI gates serve rows with a looser threshold — request latency
on shared runners is noisier than arena walls).
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.models import registry
from repro.runtime import Request, Server
from repro.runtime import faults as faults_lib
from repro.runtime.faults import SERVE_POINTS

from .bench_schema import SCHEMA_VERSION, row_key, upgrade_row

# the smoke-preset acceptance bound: faulted p99 < P99_BOUND * clean p99
P99_BOUND = 3.0

PRESETS: Dict[str, Dict[str, int]] = {
    # requests deliberately outnumber slots: every leg churns its slots
    "smoke": dict(requests=12, slots=4, max_seq=64, max_new=6,
                  max_ticks=400),
    "quick": dict(requests=24, slots=4, max_seq=64, max_new=8,
                  max_ticks=800),
    "full": dict(requests=64, slots=8, max_seq=128, max_new=16,
                 max_ticks=4000),
}

_COLS = ("leg,requests,completed,shed,timed_out,failed,retries,"
         "tokens,tokens_per_s,p50_ms,p99_ms,fallbacks")


def _mixed_prompts(rng: np.random.Generator, n: int, vocab: int,
                   max_seq: int) -> List[np.ndarray]:
    """Mixed prompt lengths spanning the pack buckets (short chat-like to
    long context-like), capped well under max_seq."""
    lens = rng.integers(3, min(25, max_seq // 2), size=n)
    return [rng.integers(0, vocab, size=int(p)).astype(np.int32)
            for p in lens]


def _drive(server: Server, reqs: List[Request],
           max_ticks: int) -> Tuple[Dict[int, float], float]:
    """Open-loop drive: one arrival per tick (the stream does not wait for
    the server), then ticks until drained.  Returns per-rid latency
    (submit -> terminal, accepted requests only) and the total wall."""
    latency: Dict[int, float] = {}
    submit_at: Dict[int, float] = {}
    seen_terminal = 0
    t0 = time.perf_counter()
    i = 0
    for _ in range(max_ticks):
        if i < len(reqs):
            submit_at[reqs[i].rid] = time.perf_counter()
            server.submit(reqs[i])
            i += 1
        more = server.tick()
        for req in server.tracker.finished()[seen_terminal:]:
            latency[req.rid] = time.perf_counter() - submit_at[req.rid]
            seen_terminal += 1
        if i >= len(reqs) and not more:
            break
    return latency, time.perf_counter() - t0


def run_leg(leg: str, preset: str, *, fault: Optional[str] = None,
            shed_watermark: Optional[int] = None, seed: int = 0,
            out=sys.stdout) -> Dict[str, Any]:
    """One open-loop leg; returns its schema-v7 row.  Asserts (not merely
    reports) the lifecycle contract: conservation, typed terminals, and —
    on faulted legs — that the server kept completing requests."""
    sizes = PRESETS[preset]
    api = registry.get("llama3.2-1b", smoke=True)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = _mixed_prompts(rng, sizes["requests"], api.cfg.vocab_size,
                             sizes["max_seq"])
    reqs = [Request(rid=i, prompt=p, max_new_tokens=sizes["max_new"])
            for i, p in enumerate(prompts)]

    def build_and_drive():
        server = Server(api, params, slots=sizes["slots"],
                        max_seq=sizes["max_seq"],
                        max_queue=2 * sizes["requests"],
                        shed_watermark=shed_watermark,
                        backoff_base_s=0.0)
        latency, wall_s = _drive(server, reqs, sizes["max_ticks"])
        return server, latency, wall_s

    if fault:
        # arrival 2 lands the kill mid-run (past the very first op) for
        # every point except policy_swap, which only trips at install
        at = 1 if fault == "serve.policy_swap" else 2
        with faults_lib.injected(fault, at=at) as inj:
            server, latency, wall_s = build_and_drive()
        assert inj.fired, f"{fault} was never reached by the {leg} leg"
    else:
        server, latency, wall_s = build_and_drive()

    stats = server.stats
    # the lifecycle contract, enforced in the benchmark itself
    server.tracker.assert_conserved()
    assert stats.terminal == stats.submitted, (
        f"{leg}: {stats.submitted} submitted but {stats.terminal} terminal")
    if fault:
        assert stats.completed > 0, (
            f"{leg}: server stopped completing requests after the fault")

    lat_ms = sorted(v * 1e3 for v in latency.values())
    p50 = float(np.percentile(lat_ms, 50)) if lat_ms else None
    p99 = float(np.percentile(lat_ms, 99)) if lat_ms else None
    tok_s = stats.tokens_generated / wall_s if wall_s > 0 else 0.0
    ledger = server.program.merged_ledger()

    row = upgrade_row(dict(
        schema=SCHEMA_VERSION,
        scenario=f"serve_open_loop_{leg}", family="serve", scheme="serve",
        spec="", policy=str(server.policy),
        first_wall_us=round(wall_s * 1e6, 1),
        cached_wall_us=round(p50 * 1e3, 1) if p50 is not None else None,
        steady_wall_us=round(p99 * 1e3, 1) if p99 is not None else None,
        speedup=None,
        h2d_bytes=ledger.h2d_bytes, h2d_calls=ledger.h2d_calls,
        enqueue_us=None, sync_us=None,
        n_devices=jax.device_count(),
        requests=stats.submitted, tokens=stats.tokens_generated,
        tokens_per_s=round(tok_s, 1),
        p50_ms=round(p50, 3) if p50 is not None else None,
        p99_ms=round(p99, 3) if p99 is not None else None,
        shed=stats.shed, timed_out=stats.timed_out, failed=stats.failed,
        retries=stats.retries_total, fault_point=fault or "",
        policy_fallbacks=stats.policy_fallbacks))
    print(f"{leg},{stats.submitted},{stats.completed},{stats.shed},"
          f"{stats.timed_out},{stats.failed},{stats.retries_total},"
          f"{stats.tokens_generated},{row['tokens_per_s']},"
          f"{row['p50_ms']},{row['p99_ms']},{stats.policy_fallbacks}",
          file=out)
    return row


def _merge_json(rows: List[dict], json_path: str, out) -> None:
    """Replace same-key rows in an existing BENCH_serve.json, keep the
    rest — reruns of a leg subset must not drop the other legs' rows."""
    existing: List[dict] = []
    if os.path.exists(json_path):
        with open(json_path) as f:
            existing = json.load(f)
    fresh = {row_key(r) for r in rows}
    merged = [r for r in existing if row_key(upgrade_row(r)) not in fresh]
    merged.extend(rows)
    with open(json_path, "w") as f:
        json.dump(merged, f, indent=2)
    print(f"[serve_load] merged {len(rows)} row(s) into {json_path} "
          f"({len(merged)} total, schema v{SCHEMA_VERSION})", file=out)


def run_bench(preset: str = "full", fault: str = "all",
              json_path: Optional[str] = None, seed: int = 0,
              out=sys.stdout) -> List[dict]:
    """The full sweep: clean + overload legs, then one leg per serve fault
    point (``fault``: "all" / "none" / one point name).  In the smoke
    preset the bounded-degradation acceptance bound is asserted: every
    faulted leg's p99 < ``P99_BOUND`` x the clean p99."""
    print(_COLS, file=out)
    rows = [run_leg("clean", preset, seed=seed, out=out)]
    clean_p99 = rows[0]["p99_ms"]
    # overload: watermark far below the arrival count -> typed shedding
    overload = run_leg("overload", preset, seed=seed,
                       shed_watermark=max(2, PRESETS[preset]["slots"] // 2),
                       out=out)
    assert overload["shed"] > 0, (
        "overload leg shed nothing: the watermark never engaged")
    rows.append(overload)
    points = (SERVE_POINTS if fault == "all"
              else () if fault == "none" else (fault,))
    for point in points:
        leg = f"fault_{point.split('.', 1)[1]}"
        row = run_leg(leg, preset, fault=point, seed=seed, out=out)
        if preset == "smoke" and clean_p99 and row["p99_ms"]:
            assert row["p99_ms"] < P99_BOUND * clean_p99, (
                f"{leg}: p99 {row['p99_ms']:.1f}ms exceeds "
                f"{P99_BOUND}x clean p99 {clean_p99:.1f}ms — "
                f"degradation is not bounded")
        rows.append(row)
    if fault == "all":
        print(f"[serve_load] {len(points)} faulted leg(s): zero "
              f"lost/duplicated requests, server stayed up", file=out)
    if json_path:
        _merge_json(rows, json_path, out)
    return rows


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="open-loop serve load benchmark (tokens/sec, p50/p99, "
                    "faulted legs with bounded degradation)")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest preset + assert the bounded-p99 and "
                         "conservation contracts (the CI legs)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--fault", default="all",
                    help="'all' (default), 'none' (clean+overload only), "
                         "or one serve.* point")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="merge rows into this BENCH_serve.json (default: "
                         "repo-root BENCH_serve.json; 'none' disables)")
    args = ap.parse_args(argv)
    preset = "smoke" if args.smoke else ("quick" if args.quick else "full")
    if args.json == "none":
        json_path = None
    elif args.json:
        json_path = args.json
    else:
        json_path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_serve.json")
    run_bench(preset=preset, fault=args.fault, json_path=json_path,
              seed=args.seed)


if __name__ == "__main__":
    main()
