"""Instruction-count analogue of paper Tables 3-4 (PTX LOC -> jaxpr + dispatch).

On the GPU each pointer dereference costs 2 instructions.  In JAX the
device program does NOT grow with chain depth — XLA dead-code-eliminates
untouched interior leaves (a hardware-adaptation finding the PGI compiler
could not make; see DESIGN.md §2.1).  What DOES grow, and what this table
measures, is the host side of the chain:

  invars       jaxpr inputs the region must marshal (the LOC analogue) —
               whole-tree regions grow ~4 entries per level, pointerchain
               regions stay flat (the paper's 'PC constant at 60 LOC'),
  dispatch_us  measured per-call dispatch latency of the jit'd region
               (pytree flatten/unflatten of the k-level tree vs. extracted
               leaves) — the 2-loads-per-dereference cost, relocated to
               where it lives on a TPU system.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.core import TreePath, declare, extract
from repro.launch.hlo_analysis import hlo_line_count
from repro.scenarios import (dense_chain, dense_tree, linear_chain,
                             linear_tree, linear_used_paths)
from .timer import bench

_SCALE = 1.0001


def _measure_whole_tree(tree, paths):
    """UVM/marshalling style: jit over the full tree; dereference inside."""
    def fn(t):
        out = t
        for p in paths:
            out = TreePath.parse(p).update(out, lambda a: a * _SCALE)
        return out
    jaxpr = jax.make_jaxpr(fn)(tree)
    lowered = jax.jit(fn).lower(tree)
    jitted = jax.jit(fn)
    # lint: allow=DC201 -- jit warmup sync before timing
    jax.block_until_ready(jax.tree_util.tree_leaves(jitted(tree))[0])
    disp = bench("whole", lambda: jitted(tree), min_time=0.05, repeats=2)
    return {"invars": len(jaxpr.jaxpr.invars), "eqns": len(jaxpr.eqns),
            "hlo_lines": hlo_line_count(lowered.as_text()),
            "dispatch_us": disp.us_per_call}


def _measure_pointerchain(tree, paths):
    refs = declare(tree, *paths)
    leaves = [jax.numpy.asarray(l) for l in extract(tree, refs)]

    def fn(*ls):
        return [l * _SCALE for l in ls]
    jaxpr = jax.make_jaxpr(fn)(*leaves)
    lowered = jax.jit(fn).lower(*leaves)
    jitted = jax.jit(fn)
    # lint: allow=DC201 -- jit warmup sync before timing
    jax.block_until_ready(jitted(*leaves)[0])
    disp = bench("pc", lambda: jitted(*leaves), min_time=0.05, repeats=2)
    return {"invars": len(jaxpr.jaxpr.invars), "eqns": len(jaxpr.eqns),
            "hlo_lines": hlo_line_count(lowered.as_text()),
            "dispatch_us": disp.us_per_call}


def run(ks=(2, 3, 4, 5, 6, 7, 8, 9, 10), n=64, out=sys.stdout):
    rows = []
    print("table,k,layout,scheme,invars,eqns,hlo_lines,dispatch_us,"
          "delta_invars_vs_uvm_pct", file=out)
    for layout in ("allinit-allused", "allinit-LLused", "LLinit-LLused"):
        for k in ks:
            tree = linear_tree(k, n, layout)
            paths = linear_used_paths(k, layout)
            whole = _measure_whole_tree(tree, paths)      # == UVM == marshal
            pc = _measure_pointerchain(tree, paths)
            for scheme, m in (("uvm", whole), ("marshal", whole),
                              ("pointerchain", pc)):
                delta = 100.0 * (m["invars"] - whole["invars"]) \
                    / max(1, whole["invars"])
                rows.append(dict(table="linear", k=k, layout=layout,
                                 scheme=scheme, **m, delta=delta))
                print(f"linear,{k},{layout},{scheme},{m['invars']},"
                      f"{m['eqns']},{m['hlo_lines']},"
                      f"{m['dispatch_us']:.1f},{delta:.0f}", file=out)
    # Dense (Table 4): one chained leaf at depth 3
    tree = dense_tree(4, n, 3)
    paths = [dense_chain(4, 3)]
    whole = _measure_whole_tree(tree, paths)
    pc = _measure_pointerchain(tree, paths)
    for scheme, m in (("uvm", whole), ("marshal", whole),
                      ("pointerchain", pc)):
        delta = 100.0 * (m["invars"] - whole["invars"]) \
            / max(1, whole["invars"])
        rows.append(dict(table="dense", k=3, layout="selective",
                         scheme=scheme, **m, delta=delta))
        print(f"dense,3,selective,{scheme},{m['invars']},{m['eqns']},"
              f"{m['hlo_lines']},{m['dispatch_us']:.1f},{delta:.0f}",
              file=out)
    return rows


if __name__ == "__main__":
    run()
