"""Registry smoke sweep: every scenario x every transfer spec at tiny sizes.

``python -m benchmarks.run --smoke`` — the CI job that catches harness
breakage (a scenario that stops building, a spec whose data motion drifts
off its analytic expectation, a check that goes vacuous) without waiting
for someone to regenerate BENCH_transfer.json.

``--spec`` narrows the sweep to the named spec strings (e.g.
``marshal+delta@dp8`` on the forced-8-device CI host); any requested delta
spec is ALSO driven through the steady-state harness of every
steady-capable scenario, so the per-device equality
``h2d_bytes_by_device[d] + skipped_bytes_by_device[d] == full sharded
marshal bytes[d]`` is checked on every device even for scenarios that
declare their own steady state unsharded.

``--policy`` adds path-scoped TransferPolicy programs to the sweep: every
scenario tree is compiled under each requested policy (every scenario's
own declared policy runs regardless) and driven cold + warm, with the
per-region three-way motion check (closed form == structural derivation
== region ledger), ONE sync per pass, and — for delta regions — the exact
per-device complement, all enforced as failures.

``--async`` (``async_executor=True``) runs every policy program a second
time through the PIPELINED executor (``to_device_async(...).result()``)
under the same contracts — the CI leg that keeps async==sync honest on
the forced-multi-device host.
"""
from __future__ import annotations

import sys
import time
from typing import List, Optional, Sequence

from repro.core import TransferPolicy, TransferSpec
from repro.scenarios import (iter_scenarios, run_policy_scenario,
                             run_scenario, run_steady_scenario)


def _steady_capable(sc) -> bool:
    return "mutate_path" in sc.params or "mutate_paths" in sc.params


def run(out=sys.stdout, size: str = "smoke",
        specs: Optional[Sequence[str]] = None,
        policies: Optional[Sequence[str]] = None,
        async_executor: bool = False) -> List[dict]:
    requested = [TransferSpec.parse(s) for s in specs] if specs else None
    req_policies = [TransferPolicy.parse(p) for p in policies] if policies \
        else []
    executors = ("blocking", "async") if async_executor else ("blocking",)
    rows: List[dict] = []
    failures: List[str] = []
    print("scenario,spec,wall_us,h2d_bytes,h2d_calls,check,motion", file=out)
    t0 = time.time()
    for sc in iter_scenarios(size):
        tree = sc.build()
        sc.validate(tree)
        # program passes: the scenario's declared policy + every requested
        # one (deduped on the canonical string) — cold, then warm
        # (mutating the steady paths when declared)
        own = [sc.policy()] if sc.declared_policy else []
        for pol in {str(p): p for p in own + req_policies}.values():
            npass = 3 if _steady_capable(sc) else 2
            for executor in executors:
                tag = f"policy/{executor}" if async_executor else "policy"
                for i, m in enumerate(run_policy_scenario(
                        sc, pol, tree=tree, passes=npass,
                        executor=executor)):
                    rows.append(dict(scenario=sc.name, spec=str(pol),
                                     scheme=f"{tag}/pass{i}",
                                     wall_us=round(m.wall_us, 1),
                                     h2d_bytes=m.h2d_bytes,
                                     h2d_calls=m.h2d_calls,
                                     ok=m.ok, motion_ok=m.motion_ok))
                    print(f"{sc.name},{tag}[{pol}]/pass{i},{m.wall_us:.1f},"
                          f"{m.h2d_bytes},{m.h2d_calls},"
                          f"{'ok' if m.ok else 'FAIL'},"
                          f"{'ok' if m.motion_ok else 'FAIL'}", file=out)
                    if not m.ok:
                        failures.append(f"{sc.name}/{tag}[{pol}]/pass{i}: "
                                        "value check failed")
                    if not m.motion_ok:
                        failures.append(
                            f"{sc.name}/{tag}[{pol}]/pass{i}: per-region "
                            f"motion broke the ledger contract ({m.regions})")
        for spec in sc.specs():
            if requested is not None and not any(
                    str(spec) == str(r) or spec.name == str(r)
                    for r in requested):
                continue
            m = run_scenario(sc, spec, tree=tree)
            rows.append(dict(scenario=sc.name, spec=str(spec),
                             scheme=spec.name,
                             wall_us=round(m.wall_us, 1),
                             h2d_bytes=m.h2d_bytes, h2d_calls=m.h2d_calls,
                             ok=m.ok, motion_ok=m.motion_ok))
            print(f"{sc.name},{spec},{m.wall_us:.1f},{m.h2d_bytes},"
                  f"{m.h2d_calls},{'ok' if m.ok else 'FAIL'},"
                  f"{'ok' if m.motion_ok else 'FAIL'}", file=out)
            if not m.ok:
                failures.append(f"{sc.name}/{spec}: value check failed")
            if not m.motion_ok:
                failures.append(
                    f"{sc.name}/{spec}: motion ({m.h2d_bytes}, {m.h2d_calls})"
                    f" != expected {m.expected.as_tuple()}")
        if not _steady_capable(sc):
            continue
        # steady-state delta contract: every warm pass ships exactly the
        # mutated region — whole dirty buckets, or under a sharded spec
        # only the dirty (bucket, device) shards — skips everything else
        # with exact per-device complements, and still round-trips the
        # mutated tree.
        steady_specs = [r for r in requested if r.delta] if requested \
            else [sc.steady_spec or TransferSpec.parse("marshal+delta")]
        for sspec in steady_specs:
            for i, m in enumerate(run_steady_scenario(sc, passes=2,
                                                      spec=sspec)):
                rows.append(dict(scenario=sc.name, spec=str(sspec),
                                 scheme=f"{sspec.name}/steady{i}",
                                 wall_us=round(m.wall_us, 1),
                                 h2d_bytes=m.h2d_bytes,
                                 h2d_calls=m.h2d_calls,
                                 ok=m.ok, motion_ok=m.motion_ok))
                print(f"{sc.name},{sspec}/steady{i},{m.wall_us:.1f},"
                      f"{m.h2d_bytes},{m.h2d_calls},"
                      f"{'ok' if m.ok else 'FAIL'},"
                      f"{'ok' if m.motion_ok else 'FAIL'}", file=out)
                if not m.ok:
                    failures.append(
                        f"{sc.name}/{sspec}/steady{i}: value check failed")
                if not m.motion_ok:
                    failures.append(
                        f"{sc.name}/{sspec}/steady{i}: steady motion "
                        f"({m.h2d_bytes}, {m.h2d_calls}, skipped "
                        f"{m.skipped_bytes}, by device {m.h2d_by_device}) "
                        f"broke the ledger contract")
    print(f"[smoke] {len(rows)} cells in {time.time() - t0:.1f}s", file=out)
    if failures:
        raise SystemExit("[smoke] FAILURES:\n  " + "\n  ".join(failures))
    return rows


if __name__ == "__main__":
    run()
