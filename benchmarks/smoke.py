"""Registry smoke sweep: every scenario x every scheme at tiny sizes.

``python -m benchmarks.run --smoke`` — the CI job that catches harness
breakage (a scenario that stops building, a scheme whose data motion
drifts off its analytic expectation, a check that goes vacuous) without
waiting for someone to regenerate BENCH_transfer.json.
"""
from __future__ import annotations

import sys
import time
from typing import List

from repro.scenarios import iter_scenarios, run_scenario, run_steady_scenario


def run(out=sys.stdout, size: str = "smoke") -> List[dict]:
    rows: List[dict] = []
    failures: List[str] = []
    print("scenario,scheme,wall_us,h2d_bytes,h2d_calls,check,motion", file=out)
    t0 = time.time()
    for sc in iter_scenarios(size):
        tree = sc.build()
        sc.validate(tree)
        for name in sc.scheme_names():
            m = run_scenario(sc, name, tree=tree)
            rows.append(dict(scenario=sc.name, scheme=name,
                             wall_us=round(m.wall_us, 1),
                             h2d_bytes=m.h2d_bytes, h2d_calls=m.h2d_calls,
                             ok=m.ok, motion_ok=m.motion_ok))
            print(f"{sc.name},{name},{m.wall_us:.1f},{m.h2d_bytes},"
                  f"{m.h2d_calls},{'ok' if m.ok else 'FAIL'},"
                  f"{'ok' if m.motion_ok else 'FAIL'}", file=out)
            if not m.ok:
                failures.append(f"{sc.name}/{name}: value check failed")
            if not m.motion_ok:
                failures.append(
                    f"{sc.name}/{name}: motion ({m.h2d_bytes}, {m.h2d_calls})"
                    f" != expected {m.expected.as_tuple()}")
        if sc.steady_expected is not None:
            # steady-state delta contract: every warm pass ships exactly
            # the dirty bucket (ledger equality), skips everything else,
            # and still round-trips the mutated tree.
            for i, m in enumerate(run_steady_scenario(sc, passes=2)):
                rows.append(dict(scenario=sc.name,
                                 scheme=f"marshal_delta/steady{i}",
                                 wall_us=round(m.wall_us, 1),
                                 h2d_bytes=m.h2d_bytes,
                                 h2d_calls=m.h2d_calls,
                                 ok=m.ok, motion_ok=m.motion_ok))
                print(f"{sc.name},marshal_delta/steady{i},{m.wall_us:.1f},"
                      f"{m.h2d_bytes},{m.h2d_calls},"
                      f"{'ok' if m.ok else 'FAIL'},"
                      f"{'ok' if m.motion_ok else 'FAIL'}", file=out)
                if not m.ok:
                    failures.append(f"{sc.name}/steady{i}: value check failed")
                if not m.motion_ok:
                    failures.append(
                        f"{sc.name}/steady{i}: steady motion ({m.h2d_bytes}, "
                        f"{m.h2d_calls}, skipped {m.skipped_bytes}) != "
                        f"expected {sc.steady_expected.as_tuple()}")
    print(f"[smoke] {len(rows)} cells in {time.time() - t0:.1f}s", file=out)
    if failures:
        raise SystemExit("[smoke] FAILURES:\n  " + "\n  ".join(failures))
    return rows


if __name__ == "__main__":
    run()
