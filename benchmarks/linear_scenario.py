"""Linear scenario (paper §4.1, Figs. 5-6): nesting depth vs. transfer scheme.

Sweeps k (chain depth) x n (payload) x layout x scheme over cells built by
the ``repro.scenarios`` registry (``linear_case`` is the single source of
truth for builders, used paths and analytic expectations); reports
wall-clock and kernel time normalized to UVM (the paper's presentation)
plus the data motion each scheme issued.  CSV: one row per cell.
"""
from __future__ import annotations

import sys
from typing import List

from repro.core import transfer_scheme
from repro.scenarios import LINEAR_LAYOUTS, PAPER_SCHEMES, linear_case, run_scenario


def run(ks=(2, 6, 10), ns=(10**3, 10**5), layouts=LINEAR_LAYOUTS,
        out=sys.stdout, repeats: int = 3) -> List[dict]:
    rows = []
    print("scenario,k,n,layout,scheme,wall_us,kernel_us,"
          "h2d_bytes,h2d_calls,norm_wall_vs_uvm", file=out)
    for k in ks:
        for n in ns:
            for layout in layouts:
                sc = linear_case(k, n, layout)
                tree = sc.build()
                base = None
                for scheme in PAPER_SCHEMES:
                    best = None
                    inst = transfer_scheme(scheme)  # reused across repeats
                    for _ in range(repeats):
                        m = run_scenario(sc, scheme, scheme=inst, tree=tree)
                        assert m.ok, f"check failed: {scheme} k={k} n={n}"
                        assert m.motion_ok, (
                            f"data motion off expectation: {scheme} k={k} "
                            f"n={n}: got ({m.h2d_bytes}, {m.h2d_calls}), "
                            f"want {m.expected.as_tuple()}")
                        if best is None or m.wall_us < best.wall_us:
                            best = m
                    if scheme == "uvm":
                        base = best.wall_us
                    rows.append(dict(k=k, n=n, layout=layout, scheme=scheme,
                                     wall_us=best.wall_us,
                                     kernel_us=best.kernel_us,
                                     h2d_bytes=best.h2d_bytes,
                                     h2d_calls=best.h2d_calls,
                                     norm=best.wall_us / base))
                    print(f"linear,{k},{n},{layout},{scheme},"
                          f"{best.wall_us:.1f},{best.kernel_us:.1f},"
                          f"{best.h2d_bytes},{best.h2d_calls},"
                          f"{best.wall_us / base:.3f}", file=out)
    return rows


if __name__ == "__main__":
    run()
