"""Steady-state transfer benchmark: first-call vs. cached-call latency.

The arena engine's claim is that the transfer *plan* is reusable metadata:
the first ``to_device`` for a tree shape pays plan + staging-alloc + compile,
every later call is pure data motion.  This section measures both, per
scheme x scenario, and (via ``benchmarks.run``) persists the rows to
``BENCH_transfer.json`` so the perf trajectory is trackable across PRs.

Ledger invariants are reported alongside: batching changes *when* we
synchronize, never how many bytes / DMA batches move.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, List, Optional

import jax

from repro.core import make_scheme

from .scenarios import (dense_chain, dense_tree, dense_uvm_access_set,
                        linear_tree, linear_used_paths)

SCHEMES = ("uvm", "marshal", "pointerchain")


def _scenarios(quick: bool = False) -> Dict[str, Dict[str, Any]]:
    out = {
        "dense_q4_n1e3": dict(
            tree=dense_tree(4, 10**3, 3),
            paths=[dense_chain(4, 3)],
            uvm_access=dense_uvm_access_set(4, 3)),
        "linear_k6_n1e3": dict(
            tree=linear_tree(6, 10**3, "allinit-allused"),
            paths=linear_used_paths(6, "allinit-allused"),
            uvm_access=None),
    }
    if not quick:
        out["dense_q8_n1e3"] = dict(
            tree=dense_tree(8, 10**3, 3),
            paths=[dense_chain(8, 3)],
            uvm_access=dense_uvm_access_set(8, 3))
    return out


def _one_transfer(scheme, name: str, tree, paths, uvm_access) -> float:
    """One full H2D pass under the scheme's policy; returns wall seconds."""
    t0 = time.perf_counter()
    if name == "uvm":
        dev = scheme.to_device(tree)
        dev = scheme.materialize(dev, paths=uvm_access or paths)
    elif name == "pointerchain":
        dev = scheme.to_device(tree, paths=paths)
    else:
        dev = scheme.to_device(tree)
    jax.block_until_ready(dev)
    return time.perf_counter() - t0


def run(out=sys.stdout, repeats: int = 5, quick: bool = False,
        json_path: Optional[str] = None) -> List[dict]:
    rows: List[dict] = []
    print("scenario,scheme,first_wall_us,cached_wall_us,speedup,"
          "h2d_bytes,h2d_calls,enqueue_us,sync_us", file=out)
    for scen, spec in _scenarios(quick).items():
        tree, paths, uvm_access = spec["tree"], spec["paths"], spec["uvm_access"]
        for name in SCHEMES:
            scheme = make_scheme(name)
            first_us = _one_transfer(scheme, name, tree, paths,
                                     uvm_access) * 1e6
            h2d_bytes, h2d_calls = (scheme.ledger.h2d_bytes,
                                    scheme.ledger.h2d_calls)
            cached, enq, syn = [], [], []
            for _ in range(repeats):
                if name == "uvm":
                    # demand paging has no persistent plan: every pass
                    # re-faults, so "cached" only measures batching gains
                    scheme = make_scheme(name)
                scheme.ledger.reset()
                cached.append(_one_transfer(scheme, name, tree, paths,
                                            uvm_access) * 1e6)
                enq.append(scheme.ledger.enqueue_s * 1e6)
                syn.append(scheme.ledger.sync_s * 1e6)
            cached_us = min(cached)
            i = cached.index(cached_us)
            row = dict(scenario=scen, scheme=name,
                       first_wall_us=round(first_us, 1),
                       cached_wall_us=round(cached_us, 1),
                       speedup=round(first_us / cached_us, 2),
                       h2d_bytes=h2d_bytes, h2d_calls=h2d_calls,
                       enqueue_us=round(enq[i], 1), sync_us=round(syn[i], 1))
            rows.append(row)
            print("{scenario},{scheme},{first_wall_us},{cached_wall_us},"
                  "{speedup},{h2d_bytes},{h2d_calls},{enqueue_us},{sync_us}"
                  .format(**row), file=out)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"[transfer_steady] wrote {json_path}", file=out)
    return rows


if __name__ == "__main__":
    run()
