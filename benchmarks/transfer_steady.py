"""Steady-state transfer benchmark: first-call vs. cached-call latency.

The arena engine's claim is that the transfer *plan* is reusable metadata:
the first ``to_device`` for a tree shape pays plan + staging-alloc + compile,
every later call is pure data motion.  This section measures both over the
ENTIRE ``repro.scenarios`` registry — one row per scheme x registered
scenario — and (via ``benchmarks.run``) persists the rows to
``BENCH_transfer.json`` so the perf trajectory is trackable across PRs.

Every row's ``h2d_bytes``/``h2d_calls`` is asserted against the scenario's
analytic expectation (DESIGN.md §4 invariant 4 makes these exact): a scheme
that silently changes its data motion fails the benchmark, not just a test.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, List, Optional

import jax

from repro.core import make_scheme
from repro.scenarios import SCHEME_NAMES, Scenario, iter_scenarios


def _one_transfer(scheme, sc: Scenario, tree: Any) -> float:
    """One full H2D pass under the scheme's policy; returns wall seconds.

    ``declare_refs=False``: the kernel's chain resolution is not data
    motion, so it stays out of the steady-state timing.
    """
    t0 = time.perf_counter()
    dev, _ = scheme.stage(tree, list(sc.used_paths),
                          uvm_access=list(sc.uvm_access)
                          if sc.uvm_access else None,
                          declare_refs=False)
    jax.block_until_ready(dev)
    return time.perf_counter() - t0


def run(out=sys.stdout, repeats: int = 5, quick: bool = False,
        json_path: Optional[str] = None, size: Optional[str] = None) -> List[dict]:
    size = size or ("quick" if quick else "full")
    rows: List[dict] = []
    print("scenario,scheme,first_wall_us,cached_wall_us,speedup,"
          "h2d_bytes,h2d_calls,enqueue_us,sync_us", file=out)
    for sc in iter_scenarios(size):
        tree = sc.build()
        for name in SCHEME_NAMES:
            scheme = make_scheme(name)
            first_us = _one_transfer(scheme, sc, tree) * 1e6
            h2d_bytes, h2d_calls = (scheme.ledger.h2d_bytes,
                                    scheme.ledger.h2d_calls)
            expected = sc.expected_motion(
                name, tree, align_elems=getattr(scheme, "align_elems", 1))
            assert (h2d_bytes, h2d_calls) == expected.as_tuple(), (
                f"{sc.name}/{name}: ledger ({h2d_bytes}, {h2d_calls}) != "
                f"analytic expectation {expected.as_tuple()}")
            cached, enq, syn = [], [], []
            for _ in range(repeats):
                if name == "uvm":
                    # demand paging has no persistent plan: every pass
                    # re-faults, so "cached" only measures batching gains
                    scheme = make_scheme(name)
                scheme.ledger.reset()
                cached.append(_one_transfer(scheme, sc, tree) * 1e6)
                enq.append(scheme.ledger.enqueue_s * 1e6)
                syn.append(scheme.ledger.sync_s * 1e6)
            cached_us = min(cached)
            i = cached.index(cached_us)
            row = dict(scenario=sc.name, family=sc.family, scheme=name,
                       first_wall_us=round(first_us, 1),
                       cached_wall_us=round(cached_us, 1),
                       speedup=round(first_us / cached_us, 2),
                       h2d_bytes=h2d_bytes, h2d_calls=h2d_calls,
                       enqueue_us=round(enq[i], 1), sync_us=round(syn[i], 1))
            rows.append(row)
            print("{scenario},{scheme},{first_wall_us},{cached_wall_us},"
                  "{speedup},{h2d_bytes},{h2d_calls},{enqueue_us},{sync_us}"
                  .format(**row), file=out)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"[transfer_steady] wrote {json_path}", file=out)
    return rows


if __name__ == "__main__":
    run()
