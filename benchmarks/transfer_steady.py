"""Steady-state transfer benchmark: first-call vs. cached-call latency.

The arena engine's claim is that the transfer *plan* is reusable metadata:
the first ``to_device`` for a tree shape pays plan + staging-alloc + compile,
every later call is pure data motion — and, since the incremental engine,
``marshal+delta`` rows show the next step: a repeat transfer whose staging
versions have not moved ships NOTHING (``skipped_bytes`` + retained device
buckets), and steady scenarios additionally report the per-pass cost when
exactly one dtype bucket (or, under ``marshal+delta@dp{k}``, only the
bucket *shards* a mutation overlaps) is dirty.  Sharded scenarios run
every spec against the whole host mesh and record the per-device split.

This section measures all of it over the ENTIRE ``repro.scenarios``
registry — one row per applicable :class:`TransferSpec` x registered
scenario, plus one PROGRAM row per scenario policy (the scenario's
declared path-scoped ``TransferPolicy`` and any ``--policy`` requests):
cold + warm ``TransferProgram`` passes with the per-region ledgers
persisted — and (via ``benchmarks.run``) persists the rows to
``BENCH_transfer.json`` in the schema-versioned format of
``benchmarks.bench_schema`` (v5: rows carry the canonical ``spec`` string,
the per-device ledger maps, for program rows the ``policy`` string +
``region_ledgers``/``steady_region_ledgers`` maps, and the pipelined
executor's ``overlap_wall_us``/``sync_offload_us``/``finish_us`` columns)
so the perf trajectory stays machine-comparable across PRs.  Program rows
additionally assert the wall-split identity ``wall_s == enqueue_s +
sync_s + finish_s`` per region — the attribution fix that keeps overlap
from double-counting barrier time.

Every row's first-pass ``h2d_bytes``/``h2d_calls`` (and per-device split,
when sharded) is asserted against the scenario's analytic expectation
(DESIGN.md §4 invariant 4 makes these exact): a scheme that silently
changes its data motion fails the benchmark, not just a test.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, List, Optional, Sequence

import jax

from repro.core import TransferLedger, TransferPolicy
from repro.scenarios import (Scenario, iter_scenarios, motion_matches,
                             run_policy_scenario, run_steady_scenario)

from .bench_schema import LEDGER_COLUMNS, SCHEMA_VERSION, upgrade_row

_COLS = ("scenario,spec,first_wall_us,cached_wall_us,speedup,h2d_bytes,"
         "h2d_calls,enqueue_us,sync_us,skipped_bytes,steady_wall_us")


def _one_transfer(scheme, sc: Scenario, tree: Any) -> float:
    """One full H2D pass under the scheme's policy; returns wall seconds.

    ``declare_refs=False``: the kernel's chain resolution is not data
    motion, so it stays out of the steady-state timing.
    """
    t0 = time.perf_counter()
    dev, _ = scheme.stage(tree, list(sc.used_paths),
                          uvm_access=list(sc.uvm_access)
                          if sc.uvm_access else None,
                          declare_refs=False)
    jax.block_until_ready(dev)  # lint: allow=DC201 -- timing the transfer itself
    return time.perf_counter() - t0


def _steady_columns(sc: Scenario, spec) -> dict:
    """steady x delta: per-pass wall/bytes with only the mutated region
    dirty, under THE ROW'S spec (so a sharded delta row's steady columns
    describe the sharded steady state, not the scenario's default)."""
    ms = run_steady_scenario(sc, passes=3, spec=spec)
    assert all(m.ok and m.motion_ok for m in ms), \
        f"{sc.name}: steady delta pass broke its ledger contract: {ms}"
    best = min(ms, key=lambda m: m.wall_us)
    return dict(steady_wall_us=round(best.wall_us, 1),
                steady_h2d_bytes=best.h2d_bytes,
                steady_skipped_bytes=best.skipped_bytes)


def _spec_requested(spec, requested: Optional[Sequence[str]]) -> bool:
    return requested is None or str(spec) in requested \
        or spec.name in requested


def _print_row(row: dict, out) -> None:
    csv = {k: ("" if v is None else v) for k, v in row.items()}
    csv["spec"] = row["spec"] or row.get("policy", "")
    print("{scenario},{spec},{first_wall_us},{cached_wall_us},"
          "{speedup},{h2d_bytes},{h2d_calls},{enqueue_us},{sync_us},"
          "{skipped_bytes},{steady_wall_us}".format(**csv), file=out)


def _ledger_of(row: dict) -> TransferLedger:
    led = TransferLedger()
    led.h2d_bytes, led.h2d_calls = row["h2d_bytes"], row["h2d_calls"]
    return led


def _merge_region_dicts(regions: dict) -> dict:
    """Sum per-region ledger dicts into the row's flat totals."""
    out = {k: 0 for k in ("h2d_bytes", "h2d_calls", "skipped_bytes",
                          "delta_calls")}
    out.update(enqueue_s=0.0, sync_s=0.0, h2d_bytes_by_device={},
               skipped_bytes_by_device={})
    for led in regions.values():
        for k in ("h2d_bytes", "h2d_calls", "skipped_bytes", "delta_calls"):
            out[k] += led[k]
        out["enqueue_s"] += led["enqueue_s"]
        out["sync_s"] += led["sync_s"]
        for field in ("h2d_bytes_by_device", "skipped_bytes_by_device"):
            for d, v in led[field].items():
                out[field][d] = out[field].get(d, 0) + v
    return out


def _assert_wall_split(sc: Scenario, policy: TransferPolicy,
                       regions: dict, m) -> None:
    """The schema-v5 attribution identity: the wall splits of one pass sum
    to the measured wall — ``wall_s == enqueue_s + sync_s + finish_s`` on
    every region ledger, with the program-level finish/overlap booked on
    top, never double-counted into the caller-visible wall."""
    for key, led in regions.items():
        total = led["enqueue_s"] + led["sync_s"] + led["finish_s"]
        assert abs(led["wall_s"] - total) < 1e-9, (
            f"{sc.name}/{policy}[{key}]: ledger wall {led['wall_s']} != "
            f"enqueue {led['enqueue_s']} + sync {led['sync_s']} + finish "
            f"{led['finish_s']} — double-counted attribution")
    # program level: the splits can never exceed the measured pass wall
    # (they are a decomposition of it, not independent timers)
    split_us = sum(led["wall_s"] for led in regions.values()) * 1e6 \
        + m.finish_us
    assert split_us <= m.wall_us * 1.001 + 50.0, (
        f"{sc.name}/{policy}: wall splits ({split_us:.1f}us) exceed the "
        f"measured pass wall ({m.wall_us:.1f}us)")


def _policy_row(sc: Scenario, tree: Any, policy: TransferPolicy,
                repeats: int) -> dict:
    """One schema-v5 program row: cold + warm TransferProgram passes under
    ``policy`` with the per-region three-way motion check enforced (closed
    form == structural derivation == region ledger, see
    ``run_policy_scenario``), plus warm PIPELINED passes for the overlap
    columns (``overlap_wall_us``/``sync_offload_us``/``finish_us``)."""
    ms = run_policy_scenario(sc, policy, tree=tree, passes=1 + repeats)
    assert all(m.ok and m.motion_ok for m in ms), (
        f"{sc.name}/{policy}: program pass broke its per-region ledger "
        f"contract: {[(m.ok, m.motion_ok) for m in ms]}")
    cold, warm = ms[0], min(ms[1:], key=lambda m: m.wall_us)
    _assert_wall_split(sc, policy, warm.regions, warm)
    # the pipelined executor over the same scenario: identical motion
    # contracts enforced, caller-visible wall + offloaded sync recorded
    ams = run_policy_scenario(sc, policy, tree=tree, passes=1 + repeats,
                              executor="async")
    assert all(m.ok and m.motion_ok for m in ams), (
        f"{sc.name}/{policy}: PIPELINED pass broke its per-region ledger "
        f"contract: {[(m.ok, m.motion_ok) for m in ams]}")
    awarm = min(ams[1:], key=lambda m: m.wall_us)
    totals = _merge_region_dicts(cold.regions)
    row = dict(schema=SCHEMA_VERSION,
               scenario=sc.name, family=sc.family, scheme="policy",
               spec="", policy=str(policy),
               first_wall_us=round(cold.wall_us, 1),
               cached_wall_us=round(warm.wall_us, 1),
               speedup=round(cold.wall_us / warm.wall_us, 2),
               enqueue_us=round(totals.pop("enqueue_s") * 1e6, 1),
               sync_us=round(totals.pop("sync_s") * 1e6, 1),
               sharded=policy.num_shards > 1,
               n_devices=policy.num_shards,
               per_device_bytes=None, per_device_calls=None,
               region_ledgers=cold.regions,
               steady_region_ledgers=warm.regions,
               steady_wall_us=round(warm.wall_us, 1),
               steady_h2d_bytes=warm.h2d_bytes,
               steady_skipped_bytes=warm.skipped_bytes,
               overlap_wall_us=round(awarm.wall_us, 1),
               sync_offload_us=round(awarm.offload_us, 1),
               finish_us=round(awarm.finish_us, 1))
    row.update(totals)
    return upgrade_row(row)


def run(out=sys.stdout, repeats: int = 5, quick: bool = False,
        json_path: Optional[str] = None, size: Optional[str] = None,
        specs: Optional[Sequence[str]] = None,
        policies: Optional[Sequence[str]] = None) -> List[dict]:
    """``specs`` (canonical spec strings or legacy scheme names) restricts
    the sweep to matching rows — the ``--spec`` CLI axis.  ``policies``
    (path-scoped policy strings, the ``--policy`` CLI axis) add one program
    row per scenario per policy, ON TOP of each scenario's own declared
    policy row (``mixed_policy`` family)."""
    size = size or ("quick" if quick else "full")
    rows: List[dict] = []
    suite = TransferLedger()      # every first pass, merged: the suite total
    print(_COLS, file=out)
    for sc in iter_scenarios(size):
        tree = sc.build()
        for spec in sc.specs():
            if not _spec_requested(spec, specs):
                continue
            scheme = sc.scheme_for(spec)
            first_us = _one_transfer(scheme, sc, tree) * 1e6
            first = scheme.ledger.as_dict()
            suite.merge(scheme.ledger)
            expected = sc.expected_motion(
                spec, tree, align_elems=getattr(scheme, "align_elems", 1))
            assert motion_matches(scheme.ledger, expected, sc.num_shards), (
                f"{sc.name}/{spec}: ledger ({first['h2d_bytes']}, "
                f"{first['h2d_calls']}, {scheme.ledger.per_device()}) != "
                f"analytic expectation {expected}")
            cached, passes = [], []
            for _ in range(repeats):
                if spec.kind == "uvm":
                    # demand paging has no persistent plan: every pass
                    # re-faults, so "cached" only measures batching gains
                    scheme = sc.scheme_for(spec)
                scheme.ledger.reset()
                cached.append(_one_transfer(scheme, sc, tree) * 1e6)
                passes.append(scheme.ledger.as_dict())
            cached_us = min(cached)
            best = passes[cached.index(cached_us)]
            row = dict(schema=SCHEMA_VERSION,
                       scenario=sc.name, family=sc.family, scheme=spec.name,
                       spec=str(spec),
                       first_wall_us=round(first_us, 1),
                       cached_wall_us=round(cached_us, 1),
                       speedup=round(first_us / cached_us, 2),
                       enqueue_us=round(best["enqueue_s"] * 1e6, 1),
                       sync_us=round(best["sync_s"] * 1e6, 1),
                       sharded=sc.sharding is not None,
                       n_devices=sc.num_shards,
                       per_device_bytes=expected.per_device_bytes,
                       per_device_calls=expected.per_device_calls)
            # ledger columns come straight from the first-pass dict (the
            # cold motion is the row's analytic identity), except the
            # delta-skip counters, which only the cached passes exercise
            row.update({k: first[k] for k in LEDGER_COLUMNS})
            for k in ("skipped_bytes", "delta_calls",
                      "skipped_bytes_by_device"):
                row[k] = best[k]
            if spec.delta and (sc.steady_expected is not None
                               or "mutate_paths" in sc.params
                               or "mutate_path" in sc.params):
                row.update(_steady_columns(sc, spec))
            row = upgrade_row(row)
            rows.append(row)
            _print_row(row, out)
        # program rows: the scenario's declared policy, plus any requested
        # (deduped on the canonical policy string)
        cand = [TransferPolicy.parse(t) for t in
                ([sc.declared_policy] if sc.declared_policy else [])
                + list(policies or [])]
        for pol in {str(p): p for p in cand}.values():
            row = _policy_row(sc, tree, pol, repeats)
            suite.merge(_ledger_of(row))
            rows.append(row)
            _print_row(row, out)
    print(f"[transfer_steady] suite cold motion: {suite.h2d_bytes} bytes "
          f"in {suite.h2d_calls} DMAs across {len(rows)} rows", file=out)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"[transfer_steady] wrote {json_path} "
              f"(schema v{SCHEMA_VERSION})", file=out)
    return rows


if __name__ == "__main__":
    run()
