"""Steady-state transfer benchmark: first-call vs. cached-call latency.

The arena engine's claim is that the transfer *plan* is reusable metadata:
the first ``to_device`` for a tree shape pays plan + staging-alloc + compile,
every later call is pure data motion — and, since the incremental engine,
``marshal_delta`` rows show the next step: a repeat transfer whose staging
versions have not moved ships NOTHING (``skipped_bytes`` + retained device
buckets), and ``steady_reuse`` scenarios additionally report the per-pass
cost when exactly one dtype bucket is dirty.  Sharded scenarios run every
scheme against the whole host mesh and record the per-device split.

This section measures all of it over the ENTIRE ``repro.scenarios``
registry — one row per applicable scheme x registered scenario — and (via
``benchmarks.run``) persists the rows to ``BENCH_transfer.json`` in the
schema-versioned format of ``benchmarks.bench_schema`` so the perf
trajectory stays machine-comparable across PRs.

Every row's first-pass ``h2d_bytes``/``h2d_calls`` (and per-device split,
when sharded) is asserted against the scenario's analytic expectation
(DESIGN.md §4 invariant 4 makes these exact): a scheme that silently
changes its data motion fails the benchmark, not just a test.
"""
from __future__ import annotations

import json
import sys
import time
from typing import Any, List, Optional

import jax

from repro.scenarios import (Scenario, iter_scenarios, motion_matches,
                             run_steady_scenario)

from .bench_schema import SCHEMA_VERSION, upgrade_row

_COLS = ("scenario,scheme,first_wall_us,cached_wall_us,speedup,h2d_bytes,"
         "h2d_calls,enqueue_us,sync_us,skipped_bytes,steady_wall_us")


def _one_transfer(scheme, sc: Scenario, tree: Any) -> float:
    """One full H2D pass under the scheme's policy; returns wall seconds.

    ``declare_refs=False``: the kernel's chain resolution is not data
    motion, so it stays out of the steady-state timing.
    """
    t0 = time.perf_counter()
    dev, _ = scheme.stage(tree, list(sc.used_paths),
                          uvm_access=list(sc.uvm_access)
                          if sc.uvm_access else None,
                          declare_refs=False)
    jax.block_until_ready(dev)
    return time.perf_counter() - t0


def _steady_columns(sc: Scenario) -> dict:
    """steady_reuse x delta: per-pass wall/bytes with ONE dirty bucket."""
    ms = run_steady_scenario(sc, passes=3)
    assert all(m.ok and m.motion_ok for m in ms), \
        f"{sc.name}: steady delta pass broke its ledger contract: {ms}"
    best = min(ms, key=lambda m: m.wall_us)
    return dict(steady_wall_us=round(best.wall_us, 1),
                steady_h2d_bytes=best.h2d_bytes)


def run(out=sys.stdout, repeats: int = 5, quick: bool = False,
        json_path: Optional[str] = None, size: Optional[str] = None) -> List[dict]:
    size = size or ("quick" if quick else "full")
    rows: List[dict] = []
    print(_COLS, file=out)
    for sc in iter_scenarios(size):
        tree = sc.build()
        for name in sc.scheme_names():
            scheme = sc.make_scheme(name)
            first_us = _one_transfer(scheme, sc, tree) * 1e6
            h2d_bytes, h2d_calls = (scheme.ledger.h2d_bytes,
                                    scheme.ledger.h2d_calls)
            expected = sc.expected_motion(
                name, tree, align_elems=getattr(scheme, "align_elems", 1))
            assert motion_matches(scheme.ledger, expected, sc.num_shards), (
                f"{sc.name}/{name}: ledger ({h2d_bytes}, {h2d_calls}, "
                f"{scheme.ledger.per_device()}) != analytic expectation "
                f"{expected}")
            cached, enq, syn, skip, dcalls = [], [], [], [], []
            for _ in range(repeats):
                if name == "uvm":
                    # demand paging has no persistent plan: every pass
                    # re-faults, so "cached" only measures batching gains
                    scheme = sc.make_scheme(name)
                scheme.ledger.reset()
                cached.append(_one_transfer(scheme, sc, tree) * 1e6)
                enq.append(scheme.ledger.enqueue_s * 1e6)
                syn.append(scheme.ledger.sync_s * 1e6)
                skip.append(scheme.ledger.skipped_bytes)
                dcalls.append(scheme.ledger.delta_calls)
            cached_us = min(cached)
            i = cached.index(cached_us)
            row = dict(schema=SCHEMA_VERSION,
                       scenario=sc.name, family=sc.family, scheme=name,
                       first_wall_us=round(first_us, 1),
                       cached_wall_us=round(cached_us, 1),
                       speedup=round(first_us / cached_us, 2),
                       h2d_bytes=h2d_bytes, h2d_calls=h2d_calls,
                       enqueue_us=round(enq[i], 1), sync_us=round(syn[i], 1),
                       skipped_bytes=skip[i], delta_calls=dcalls[i],
                       sharded=sc.sharding is not None,
                       n_devices=sc.num_shards,
                       per_device_bytes=expected.per_device_bytes,
                       per_device_calls=expected.per_device_calls)
            if name == "marshal_delta" and sc.steady_expected is not None:
                row.update(_steady_columns(sc))
            row = upgrade_row(row)
            rows.append(row)
            csv = {k: ("" if v is None else v) for k, v in row.items()}
            print("{scenario},{scheme},{first_wall_us},{cached_wall_us},"
                  "{speedup},{h2d_bytes},{h2d_calls},{enqueue_us},{sync_us},"
                  "{skipped_bytes},{steady_wall_us}".format(**csv), file=out)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"[transfer_steady] wrote {json_path} "
              f"(schema v{SCHEMA_VERSION})", file=out)
    return rows


if __name__ == "__main__":
    run()
