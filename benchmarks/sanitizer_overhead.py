"""Sanitizer overhead benchmark (the DESIGN.md §13.3 contract).

Two measurements, two thresholds:

* **steady microloop** (default): back-to-back ``TransferProgram`` passes
  — the most hook-dense path possible (every pass is nothing BUT packs,
  fences, enqueues and drains).  True overhead here is the sanitizer's
  bandwidth tax (one word-fold fingerprint over moved bytes, an amortized
  byte-compare over identity-skipped bytes): ~10% of a pure-transfer
  pass, riding on host timing noise of the same magnitude.  The gate is
  :data:`MICRO_BOUND` — generous enough to be noise-proof, tight enough
  to catch a bandwidth regression in the hooks (the original crc32
  fingerprint measured +109% here).

* **``--smoke``**: wall time of ``benchmarks.run --smoke`` with
  ``REPRO_SANITIZE=1`` vs. without, interleaved trials.  This is the
  workload the <10% :data:`OVERHEAD_CONTRACT` of DESIGN.md §13.3 is
  defined over, and what EXPERIMENTS.md records.

Run::

    PYTHONPATH=src python -m benchmarks.sanitizer_overhead [--smoke]

Exit status is non-zero when the applicable threshold breaks, so CI can
gate on it directly.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
from typing import Optional, Sequence

import numpy as np

from repro.analysis import sanitizer
from repro.core.engine import TransferSession

from .timer import bench

#: the DESIGN.md §13.3 contract, over the ``--smoke`` workload.
OVERHEAD_CONTRACT = 0.10
#: regression tripwire for the hook-dense steady microloop (see module doc).
MICRO_BOUND = 0.50

POLICY = "params/**=marshal+db; opt/**=marshal+delta; **=marshal+db"


def _tree(n: int):
    rng = np.random.default_rng(0)
    return {
        "params": {"w": rng.standard_normal(n).astype(np.float32),
                   "b": rng.standard_normal(n // 8).astype(np.float32)},
        "opt": {"m": rng.standard_normal(n).astype(np.float64),
                "v": rng.standard_normal(n).astype(np.float64)},
    }


def _steady_pass_us(n: int, *, sanitize: bool, min_time: float) -> float:
    """Mean us/pass of a steady mutate-then-ship program loop."""
    prev = sanitizer._ACTIVE
    sanitizer._ACTIVE = None
    if sanitize:
        sanitizer.enable(fresh=True)
    try:
        session = TransferSession()
        tree = _tree(n)
        program = session.compile(tree, POLICY)
        program.to_device(tree)

        def one_pass():
            # one dirty region per pass: params/w changes, opt stays
            # identity-clean so both the pack path and the delta
            # identity-skip path are exercised every iteration
            tree["params"]["w"] = tree["params"]["w"] + 1.0
            program.to_device(tree)

        return bench(f"steady_pass[san={'on' if sanitize else 'off'}]",
                     one_pass, min_time=min_time).us_per_call
    finally:
        sanitizer._ACTIVE = prev


def run_micro(n: int = 65536, min_time: float = 0.2, trials: int = 3) -> dict:
    # interleave the off/on legs and take each side's MIN: host-level noise
    # (frequency scaling, allocator state) moves both legs together between
    # trials, and the min is the standard robust microbenchmark statistic —
    # a single-shot ratio of two adaptive timings is noise-dominated here.
    off, on = [], []
    for _ in range(trials):
        off.append(_steady_pass_us(n, sanitize=False, min_time=min_time))
        on.append(_steady_pass_us(n, sanitize=True, min_time=min_time))
    overhead = min(on) / min(off) - 1.0
    return {"n_elems": n, "off_us": min(off), "on_us": min(on),
            "overhead": overhead, "bound": MICRO_BOUND}


def _smoke_seconds(sanitize: bool) -> float:
    env = dict(os.environ)
    env["REPRO_SANITIZE"] = "1" if sanitize else "0"
    t0 = time.perf_counter()
    subprocess.run([sys.executable, "-m", "benchmarks.run", "--smoke"],
                   env=env, stdout=subprocess.DEVNULL,
                   stderr=subprocess.DEVNULL, check=True)
    return time.perf_counter() - t0


def run_smoke(trials: int = 3) -> dict:
    off, on = [], []
    for _ in range(trials):
        off.append(_smoke_seconds(False))
        on.append(_smoke_seconds(True))
    overhead = min(on) / min(off) - 1.0
    return {"off_s": min(off), "on_s": min(on), "overhead": overhead,
            "contract": OVERHEAD_CONTRACT}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.sanitizer_overhead")
    ap.add_argument("--smoke", action="store_true",
                    help="measure over benchmarks.run --smoke (the DESIGN "
                         "§13.3 contract workload) instead of the microloop")
    ap.add_argument("--n", type=int, default=65536,
                    help="microloop: elements per large leaf")
    ap.add_argument("--min-time", type=float, default=0.2)
    ap.add_argument("--trials", type=int, default=3)
    args = ap.parse_args(argv)

    if args.smoke:
        r = run_smoke(args.trials)
        print(f"benchmarks.run --smoke: off={r['off_s']:.2f}s "
              f"on={r['on_s']:.2f}s overhead={r['overhead']:+.1%} "
              f"(contract <{r['contract']:.0%})")
        bad = r["overhead"] >= r["contract"]
    else:
        r = run_micro(args.n, args.min_time, args.trials)
        print(f"steady program pass, n={r['n_elems']}: "
              f"off={r['off_us']:.1f}us on={r['on_us']:.1f}us "
              f"overhead={r['overhead']:+.1%} (tripwire <{r['bound']:.0%}; "
              f"the <{OVERHEAD_CONTRACT:.0%} contract is over --smoke)")
        bad = r["overhead"] >= r["bound"]
    if bad:
        print("OVERHEAD THRESHOLD BROKEN", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
