"""Shared machinery for the Linear and Dense scenarios (paper §4).

Builds the paper's data-structure trees as pytrees, runs Algorithm 2
(alloc -> init -> transfer -> kernel -> transfer-back -> check) under each
transfer scheme, and measures wall clock, kernel time and data motion.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (TreePath, chain_jit, declare, extract, insert,
                        make_scheme)


# ---------------------------------------------------------------------------
# tree builders
# ---------------------------------------------------------------------------

def linear_tree(k: int, n: int, layout: str) -> Any:
    """Fig. 3: L1 -> ... -> Lk, each level with header + payload A[n].

    layout: allinit-allused | allinit-LLused | LLinit-LLused
    """
    all_init = layout.startswith("allinit")
    tree = None
    for level in range(k, 0, -1):
        init = all_init or level == k
        node = {"nA": np.int32(n), "nL": np.int32(level),
                "pad": np.zeros(4, np.int32),
                "A": np.random.default_rng(level).standard_normal(
                    n if init else 1).astype(np.float32)}
        if tree is not None:
            node["Lnext"] = tree
        tree = node
    return {"L1": tree}


def linear_chain(k: int) -> str:
    return "L1" + ".Lnext" * (k - 1) + ".A"


def linear_used_paths(k: int, layout: str) -> List[str]:
    if layout.endswith("allused"):
        return ["L1" + ".Lnext" * (i - 1) + ".A" for i in range(1, k + 1)]
    return [linear_chain(k)]


def dense_tree(q: int, n: int, depth: int = 3) -> Any:
    """Fig. 4: each level is an ARRAY of q structures; leaves carry A[n]."""
    def build(d):
        if d == 0:
            return {"nA": np.int32(n),
                    "A": np.zeros(n, np.float32)}
        return {"nA": np.int32(n), "nL": np.int32(q),
                "A": np.zeros(n, np.float32),
                "Lnext": [build(d - 1) for _ in range(q)]}
    return {"a0": build(depth)}


def dense_chain(q: int, depth: int = 3) -> str:
    return "a0" + "".join(f".Lnext[{q - 1}]" for _ in range(depth)) + ".A"


def dense_uvm_access_set(q: int, depth: int = 3) -> List[str]:
    """UVM faults the pages touched while dereferencing the chain: the
    headers of every node along it, plus the final A array."""
    out = []
    prefix = "a0"
    for _ in range(depth):
        out.append(prefix + ".nA")
        out.append(prefix + ".nL")
        prefix += f".Lnext[{q - 1}]"
    out.append(prefix + ".nA")
    out.append(prefix + ".A")
    return out


# ---------------------------------------------------------------------------
# Algorithm 2 under a transfer scheme
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Measurement:
    scheme: str
    wall_us: float
    kernel_us: float
    h2d_bytes: int
    h2d_calls: int
    ok: bool


_SCALE = 1.0001


def _scale_fn(*leaves):
    return [l * _SCALE for l in leaves]


# compiled once at module scope: repeats / sweep cells share the executable
# (per-arity/shape recompiles are handled by jit's own cache)
_KERNEL = jax.jit(_scale_fn)


def run_algorithm2(tree: Any, used_paths: List[str], scheme_name: str, *,
                   uvm_access: Optional[List[str]] = None,
                   kernel_repeats: int = 1,
                   scheme: Optional[Any] = None) -> Measurement:
    """One full Algorithm-2 pass; returns wall/kernel time + motion stats.

    Pass ``scheme`` to reuse a scheme instance (and with it the arena
    engine's cached layouts / staging buffers / compiled kernels) across
    repeats — the steady-state the engine is built for.  The ledger is reset
    so the returned Measurement still reports per-pass data motion.
    """
    if scheme is None:
        scheme = make_scheme(scheme_name)
    scheme.ledger.reset()
    refs = declare(tree, *used_paths)
    kernel = _KERNEL

    t0 = time.perf_counter()
    if scheme_name == "uvm":
        dev = scheme.to_device(tree)
        dev = scheme.materialize(dev, paths=uvm_access or used_paths)
        leaves = extract(dev, refs)
        out_leaves = kernel(*leaves)
        jax.block_until_ready(out_leaves)
        dev = insert(dev, refs, out_leaves)
        host = scheme.from_device(dev, tree)
    elif scheme_name == "marshal":
        dev = scheme.to_device(tree)
        leaves = extract(dev, refs)
        out_leaves = kernel(*leaves)
        jax.block_until_ready(out_leaves)
        dev = insert(dev, refs, out_leaves)
        host = scheme.from_device(dev, tree)
    else:  # pointerchain: move ONLY the declared chains
        dev = scheme.to_device(tree, paths=used_paths)
        leaves = scheme.extract_leaves(dev)
        out_leaves = kernel(*leaves)
        jax.block_until_ready(out_leaves)
        dev = insert(dev, scheme.refs, out_leaves)
        host = scheme.from_device(dev, tree)
    wall = (time.perf_counter() - t0) * 1e6

    # check step (Algorithm 2, line 7)
    ok = True
    for p in used_paths:
        got = np.asarray(TreePath.parse(p).resolve(host))
        want = np.asarray(TreePath.parse(p).resolve(tree)) * _SCALE
        ok &= bool(np.allclose(got, want, rtol=1e-5))

    # kernel-only time on device-resident data
    dev_leaves = [jax.device_put(np.asarray(l)) for l in extract(tree, refs)]
    jax.block_until_ready(kernel(*dev_leaves))
    t0 = time.perf_counter()
    for _ in range(max(1, kernel_repeats)):
        out = kernel(*dev_leaves)
    jax.block_until_ready(out)
    kernel_us = (time.perf_counter() - t0) / max(1, kernel_repeats) * 1e6

    return Measurement(scheme_name, wall, kernel_us,
                       scheme.ledger.h2d_bytes, scheme.ledger.h2d_calls, ok)
